"""End-to-end trainer: DSSP-SPMD pipeline + controller + checkpoints.

Runs on anything from 1 CPU device (smoke/reduced configs — this
container) to the production mesh (full configs — the same step bundle
the dry-run compiles).  The synchronization mode is first-class:

    --sync bsp    psum-every-step baseline
    --sync ssp    delayed-gradient pipeline, fixed delay = s_lower
    --sync dssp   delayed-gradient pipeline, delay re-tuned every step by
                  DsspScheduleController from measured step/collective
                  times (no recompile: the delay is a traced scalar)

Fault tolerance: atomic async checkpoints every ``save_every`` steps
(params, optimizer state, DSSP ring buffer, data cursor); ``--resume``
restores all of it and continues bit-exact w.r.t. the data stream.
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.core import dssp_spmd
from repro.data.synthetic import DataConfig, batches, loss_floor
from repro.models import registry
from repro.models.sharding import use_rules
from repro.obs.trace import TRACE
from repro.optim import make_optimizer
from repro.optim.compression import make_compressor


@dataclasses.dataclass
class TrainLog:
    steps: List[int] = dataclasses.field(default_factory=list)
    losses: List[float] = dataclasses.field(default_factory=list)
    delays: List[int] = dataclasses.field(default_factory=list)
    step_times: List[float] = dataclasses.field(default_factory=list)

    def record(self, step, loss, delay, dt):
        self.steps.append(step)
        self.losses.append(float(loss))
        self.delays.append(int(delay))
        self.step_times.append(dt)


class Trainer:
    def __init__(self, cfg, data_cfg: DataConfig, *, sync: str = "dssp",
                 s_lower: int = 0, s_upper: int = 3, lr: float = 3e-3,
                 optimizer: Optional[str] = None,
                 checkpoint_dir: Optional[str] = None, keep: int = 3,
                 save_every: int = 50, rules=None,
                 compressor: str = "none",
                 collective_time_fn: Optional[Callable[[], float]] = None,
                 staleness_damping: bool = True):
        if sync not in ("bsp", "ssp", "dssp"):
            raise ValueError(f"sync {sync!r} not trainable in SPMD mode "
                             "(asp exists in the PS layer only)")
        self.cfg = cfg
        self.data_cfg = data_cfg
        self.sync = sync
        self.s_lower, self.s_upper = s_lower, s_upper
        self.use_pipeline = sync in ("ssp", "dssp")
        self.rules = rules
        self.controller = dssp_spmd.DsspScheduleController(
            max(s_lower, 1) if self.use_pipeline else 0, s_upper)
        self.collective_time_fn = collective_time_fn or (lambda: 0.0)
        self.compressor = make_compressor(compressor)
        self.log = TrainLog()

        opt_kw = {}
        opt_name = optimizer or cfg.optimizer
        if opt_name in ("momentum", "adamw", "sgd"):
            opt_kw["staleness_damping"] = staleness_damping
        self.opt = make_optimizer(opt_name, lr, **opt_kw)
        self.loss_fn = registry.loss_fn(cfg)

        self.params = registry.init_params(cfg, jax.random.PRNGKey(0))
        self.opt_state = self.opt.init(self.params)
        if self.use_pipeline:
            grads_like = jax.eval_shape(lambda p: p, self.params)
            zero = jax.tree_util.tree_map(
                lambda s: jnp.zeros(s.shape, s.dtype), grads_like)
            self.pipeline = dssp_spmd.init_pipeline(zero, s_upper + 1)
        else:
            self.pipeline = ()
        # identity compressor: keep the jitted step's error operand empty
        # instead of threading a dead params-sized buffer through it
        self.err_state = (self.compressor.init_error(self.params)
                          if self.compressor.name != "none" else ())
        self.step_idx = 0

        self.ckpt = (CheckpointManager(checkpoint_dir, keep=keep)
                     if checkpoint_dir else None)
        self.save_every = save_every
        self._jit_step = self._build_step()

    # ------------------------------------------------------------ step fn
    def _build_step(self):
        opt, loss_fn = self.opt, self.loss_fn
        use_pipeline = self.use_pipeline
        compressor = self.compressor
        rules = self.rules

        def step(params, opt_state, pipeline, err, batch, delay):
            with use_rules(rules):
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
                if compressor.name != "none":
                    grads, err = compressor.apply(grads, err)
                if use_pipeline:
                    grads, valid, pipeline = dssp_spmd.push_pop(
                        pipeline, grads, delay)
                    staleness, lr_scale = delay, valid
                else:
                    staleness, lr_scale = 0, 1.0
                params, opt_state = opt.update(
                    grads, opt_state, params, staleness=staleness,
                    lr_scale=lr_scale)
            return params, opt_state, pipeline, err, loss

        return jax.jit(step, donate_argnums=(0, 1, 2, 3))

    # ------------------------------------------------------------ resume
    def resume(self) -> bool:
        if self.ckpt is None:
            return False
        state_like = {"params": self.params, "opt": self.opt_state,
                      "pipeline": self.pipeline}
        got = self.ckpt.restore_latest(state_like)
        if got is None:
            return False
        step, tree, extras = got
        self.params = tree["params"]
        self.opt_state = jax.tree_util.tree_map(
            jnp.asarray, tree["opt"])
        self.pipeline = jax.tree_util.tree_map(
            jnp.asarray, tree["pipeline"])
        self.step_idx = extras["next_step"]
        return True

    # ------------------------------------------------------------ train
    def train(self, n_steps: int, *, log_every: int = 10,
              verbose: bool = False) -> TrainLog:
        it = batches(self.cfg, self.data_cfg, start_step=self.step_idx)
        end = self.step_idx + n_steps
        while self.step_idx < end:
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            if self.sync == "dssp":
                delay = self.controller.delay()
            elif self.sync == "ssp":
                delay = max(self.s_lower, 1)
            else:
                delay = 0
            t_tr = TRACE.now() if TRACE.enabled else 0.0
            t0 = time.monotonic()
            (self.params, self.opt_state, self.pipeline,
             self.err_state, loss) = self._jit_step(
                self.params, self.opt_state, self.pipeline,
                self.err_state, batch, jnp.int32(delay))
            loss = jax.block_until_ready(loss)
            dt = time.monotonic() - t0
            if TRACE.enabled:
                TRACE.span("compute_step", t_tr, worker=0,
                           clock=self.step_idx,
                           args={"loss": float(loss), "delay": int(delay)})
            self.controller.observe(dt, self.collective_time_fn())
            self.log.record(self.step_idx, loss, delay, dt)
            if verbose and self.step_idx % log_every == 0:
                print(f"step {self.step_idx:5d} loss {float(loss):.4f} "
                      f"delay {delay} dt {dt * 1e3:.0f}ms")
            self.step_idx += 1
            if (self.ckpt is not None and self.save_every
                    and self.step_idx % self.save_every == 0):
                self.save()
        if self.ckpt is not None:
            self.save()
            self.ckpt.wait()
        return self.log

    def save(self) -> None:
        self.ckpt.save(self.step_idx, {
            "params": self.params, "opt": self.opt_state,
            "pipeline": self.pipeline,
        }, extras={"next_step": self.step_idx,
                   "data_seed": self.data_cfg.seed})


# ----------------------------------------------------- sharded-PS path
def train_ps(cfg, data_cfg: DataConfig, *, sync: str, n_steps: int,
             lr: float, n_shards: int, n_workers: int = 4,
             s_lower: int = 0, s_upper: int = 3,
             compressor: str = "none", apply_mode: str = "tree",
             gating: str = "sharded", straggler: float = 1.0,
             wire_format: str = "tree", transport: str = "inproc",
             arch: Optional[str] = None, smoke: bool = True,
             verbose: bool = False):
    """Deprecated shim over ``repro.api.build_session``.

    The PS training path lives in the session engines now
    (``repro.api.session``); this wrapper keeps the old keyword surface
    alive, translates it into a ``RunSpec`` and returns the trained
    session's server (the old return value).
    """
    import warnings

    from repro import api

    warnings.warn(
        "train_ps is deprecated; build a repro.api.RunSpec and call "
        "build_session(spec).run(steps) instead (see "
        "src/repro/api/README.md)", DeprecationWarning, stacklevel=2)
    if transport != "inproc" and arch is None:
        raise ValueError("transport workers rebuild the model from its "
                         "config name — pass arch=")
    spec = spec_from_flags(
        arch=arch or cfg.name, smoke=smoke, sync=sync,
        seq=data_cfg.seq_len, batch=data_cfg.global_batch,
        seed=data_cfg.seed, lr=lr, s_lower=s_lower, s_upper=s_upper,
        compress=compressor, ps_shards=max(1, n_shards),
        ps_workers=n_workers, ps_apply=apply_mode, ps_wire=wire_format,
        ps_gating=gating, ps_straggler=straggler, transport=transport)
    session = api.build_session(spec, verbose=verbose)
    session.run(n_steps)
    return session.server


# ------------------------------------------------------- flags -> spec
def spec_from_flags(*, arch: str, smoke: bool = True, sync: str = "dssp",
                    model_kernels: str = "auto",
                    seq: int = 64, batch: int = 8,
                    seed: int = 0, lr: float = 3e-3,
                    optimizer: Optional[str] = None,
                    s_lower: int = 0, s_upper: int = 3,
                    compress: str = "none", ps_shards: int = 0,
                    ps_workers: int = 4, ps_apply: str = "tree",
                    ps_wire: str = "tree", ps_gating: str = "sharded",
                    ps_straggler: float = 1.0, ps_coalesce: int = 1,
                    delta_pull: bool = False,
                    transport: str = "inproc",
                    trace_path: str = "",
                    ckpt_dir: str = "", snapshot_every: float = 5.0,
                    resume: bool = False):
    """Translate the historical CLI flag surface into a ``RunSpec``.

    Keeps the old implication chain (`--transport tcp` implies the
    packed wire; packed wire implies the fused apply; process
    transports imply `--ps-shards 1`) so every flag combination that
    used to run still runs — the spec layer itself is stricter and
    rejects the un-implied combinations outright.
    """
    from repro import api

    if transport != "inproc" and ps_shards < 1:
        ps_shards = 1          # process transports live in the PS layer
    if transport != "inproc":
        ps_wire = "packed"     # frames carry the packed buffer only
    if (ps_coalesce > 1 or delta_pull) and ps_shards < 1:
        # No implication here: silently switching the SPMD pipeline to
        # a parameter server (or dropping the knob) would train a
        # different run than the user asked for.
        raise ValueError(
            "--ps-coalesce/--delta-pull act on the parameter server's "
            "packed hot path; the SPMD pipeline has no server — add "
            "--ps-shards N (or --transport tcp/shmem)")
    if ps_coalesce > 1 or delta_pull:
        ps_wire = "packed"     # both knobs ride the packed wire
    if ps_wire == "packed" and ps_apply == "tree":
        ps_apply = "fused"     # packed pushes fold through the kernel
    if ckpt_dir and ps_shards >= 1 and ps_apply == "tree":
        ps_apply = "fused"     # snapshots capture the packed store
    ft = (api.FtSpec(snapshot_every_s=snapshot_every, dir=ckpt_dir,
                     resume=resume)
          if ckpt_dir and ps_shards >= 1 else api.FtSpec())
    if ps_shards >= 1:
        ps = api.ServerSpec(kind="sharded", shards=ps_shards,
                            workers=ps_workers, apply=ps_apply,
                            gating=ps_gating, straggler=ps_straggler,
                            coalesce=ps_coalesce)
        opt = api.OptimizerSpec(lr=lr)
    else:
        ps = api.ServerSpec(kind="none", shards=0, workers=ps_workers)
        opt = api.OptimizerSpec(name=optimizer, lr=lr)
    return api.RunSpec(
        model=api.ModelSpec(arch=arch, smoke=smoke, kernels=model_kernels),
        data=api.DataSpec(seq_len=seq, global_batch=batch, seed=seed),
        optimizer=opt,
        sync=api.SyncSpec(mode=sync, staleness=max(s_lower, 1),
                          s_lower=s_lower, s_upper=s_upper),
        ps=ps,
        wire=api.WireSpec(format=ps_wire if ps_shards >= 1 else "tree",
                          compression=compress,
                          delta_pull=delta_pull and ps_shards >= 1),
        transport=api.TransportSpec(kind=transport),
        obs=api.ObsSpec(trace=bool(trace_path), trace_path=trace_path),
        ft=ft)


# -------------------------------------------------------------------- CLI
def main() -> None:
    from repro import api

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--spec", default="", metavar="RUN.json",
                    help="load the whole run from a RunSpec JSON file "
                         "(repro.api); every other wiring flag is then "
                         "rejected — the spec is the single source of "
                         "truth")
    ap.add_argument("--dump-spec", action="store_true",
                    help="print the RunSpec these flags denote as JSON "
                         "and exit (seed a --spec file)")
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="reduced config (full configs need a TPU mesh)")
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--sync", default="dssp",
                    choices=["bsp", "ssp", "dssp", "asp"],
                    help="asp is valid only with --ps-shards (PS layer)")
    ap.add_argument("--model-kernels", default="auto", metavar="SPEC",
                    help="worker-step kernel dispatch (repro.kernels."
                         "registry): 'auto' picks per backend; a bare "
                         "variant ('pallas'/'xla') applies to every op; "
                         "per-op overrides compose as e.g. "
                         "'attention=pallas,ssm_scan=xla_associative'")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default=None)
    ap.add_argument("--s-lower", type=int, default=0)
    ap.add_argument("--s-upper", type=int, default=3)
    ap.add_argument("--compress", default="none",
                    choices=["none", "int8", "topk"])
    ap.add_argument("--checkpoint-dir", default="",
                    help="SPMD trainer state checkpoints (see --ckpt-dir "
                         "for the parameter-server engines)")
    ap.add_argument("--save-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true",
                    help="resume from the latest checkpoint "
                         "(--checkpoint-dir on SPMD, --ckpt-dir on the "
                         "PS engines)")
    ap.add_argument("--ckpt-dir", default="", metavar="DIR",
                    help="parameter-server snapshots (repro.ft): "
                         "periodically checkpoint the packed shard "
                         "store + version vector + sync-policy state "
                         "here; with --resume, restore the latest "
                         "snapshot before serving (needs --ps-shards)")
    ap.add_argument("--snapshot-every", type=float, default=5.0,
                    metavar="SECONDS",
                    help="server snapshot interval for --ckpt-dir")
    ap.add_argument("--ps-shards", type=int, default=0, metavar="N",
                    help="train through a sharded threaded parameter "
                         "server with N shards (0 = SPMD pipeline path)")
    ap.add_argument("--ps-workers", type=int, default=4)
    ap.add_argument("--ps-apply", default="tree", choices=["tree", "fused"],
                    help="per-shard apply: tree_map or one fused Pallas "
                         "launch over the packed shard (fused runs in "
                         "interpret mode on CPU — correctness validation "
                         "only; native speed needs TPU)")
    ap.add_argument("--ps-wire", default="tree", choices=["tree", "packed"],
                    help="push/pull wire format: per-leaf pytrees, or the "
                         "zero-repack packed (rows, 512) buffer (packed "
                         "implies --ps-apply fused; --compress becomes the "
                         "fused wire compression)")
    ap.add_argument("--ps-gating", default="sharded",
                    choices=["sharded", "global"])
    ap.add_argument("--ps-straggler", type=float, default=1.0,
                    help="speed factor of the last PS worker (>1 = slower)")
    ap.add_argument("--ps-coalesce", type=int, default=1, metavar="K",
                    help="coalescing window: fold up to K concurrent "
                         "workers' packed pushes through ONE batched "
                         "kernel launch per shard (implies --ps-wire "
                         "packed; 1 = one launch per push)")
    ap.add_argument("--delta-pull", action="store_true",
                    help="version-delta pulls: workers pull only the "
                         "shard regions that advanced since their last "
                         "pull (implies --ps-wire packed)")
    ap.add_argument("--trace", default="", metavar="PATH",
                    help="record a run-wide trace (repro.obs) and write "
                         "it here on exit: .jsonl = raw event lines, "
                         "anything else = Chrome trace_event JSON "
                         "(load in Perfetto / chrome://tracing)")
    ap.add_argument("--transport", default="inproc",
                    choices=["inproc", "tcp", "shmem"],
                    help="PS worker isolation: inproc = threads sharing "
                         "the heap (the classic path); tcp/shmem = spawned "
                         "worker PROCESSES pushing packed frames over a "
                         "real wire (implies --ps-wire packed; enables "
                         "--ps-shards 1 if unset)")
    args = ap.parse_args()

    if args.spec:
        # Every wiring flag (anything a RunSpec field covers) is
        # rejected alongside --spec; only run-control flags (--steps,
        # checkpointing, --dump-spec) compose with it.
        wired = [flag for flag, default, got in (
            ("--arch", "xlstm-125m", args.arch),
            ("--full", True, args.smoke),
            ("--sync", "dssp", args.sync),
            ("--model-kernels", "auto", args.model_kernels),
            ("--batch", 8, args.batch),
            ("--seq", 64, args.seq),
            ("--lr", 3e-3, args.lr),
            ("--optimizer", None, args.optimizer),
            ("--s-lower", 0, args.s_lower),
            ("--s-upper", 3, args.s_upper),
            ("--compress", "none", args.compress),
            ("--ps-shards", 0, args.ps_shards),
            ("--ps-workers", 4, args.ps_workers),
            ("--ps-apply", "tree", args.ps_apply),
            ("--ps-wire", "tree", args.ps_wire),
            ("--ps-gating", "sharded", args.ps_gating),
            ("--ps-straggler", 1.0, args.ps_straggler),
            ("--ps-coalesce", 1, args.ps_coalesce),
            ("--delta-pull", False, args.delta_pull),
            ("--trace", "", args.trace),
            ("--transport", "inproc", args.transport),
            ("--ckpt-dir", "", args.ckpt_dir),
            ("--snapshot-every", 5.0, args.snapshot_every)) \
            if got != default]
        if wired:
            ap.error(f"--spec is the single source of truth; drop "
                     f"{', '.join(wired)} (edit the JSON instead)")
        with open(args.spec) as f:
            spec = api.RunSpec.from_json(f.read())
    else:
        spec = spec_from_flags(
            arch=args.arch, smoke=args.smoke, sync=args.sync,
            model_kernels=args.model_kernels,
            seq=args.seq, batch=args.batch, lr=args.lr,
            optimizer=args.optimizer, s_lower=args.s_lower,
            s_upper=args.s_upper, compress=args.compress,
            ps_shards=args.ps_shards, ps_workers=args.ps_workers,
            ps_apply=args.ps_apply, ps_wire=args.ps_wire,
            ps_gating=args.ps_gating, ps_straggler=args.ps_straggler,
            ps_coalesce=args.ps_coalesce, delta_pull=args.delta_pull,
            transport=args.transport, trace_path=args.trace,
            ckpt_dir=args.ckpt_dir, snapshot_every=args.snapshot_every,
            resume=args.resume)
    if args.dump_spec:
        print(spec.to_json())
        return

    cfg = (get_smoke_config(spec.model.arch) if spec.model.smoke
           else get_config(spec.model.arch))

    if spec.engine != "spmd":
        ignored = [flag for flag, on in (
            ("--checkpoint-dir", bool(args.checkpoint_dir)),
            ("--resume", args.resume and not spec.ft.snapshots),
            ("--optimizer", args.optimizer is not None)) if on]
        if ignored:
            print(f"warning: {', '.join(ignored)} only apply to the SPMD "
                  "path and are ignored with --ps-shards (the PS server "
                  "optimizer is SGD/momentum; the PS snapshot dir is "
                  "--ckpt-dir, and --resume works with it)")
        print(f"arch={cfg.name} sync={spec.sync.mode} "
              f"ps_shards={spec.ps.shards} workers={spec.ps.workers} "
              f"params={registry.count_params(cfg):,}")
        with api.build_session(spec, verbose=True) as session:
            session.start()
            rig = getattr(session, "ft_rig", None)
            if spec.ft.resume and rig is not None:
                at = rig.resumed_step
                print(f"resume: {'ok, at server version ' + str(at) if at is not None else 'no snapshot'}")
            m = session.run(args.steps)
        if spec.ft.snapshots and "ft" in m:
            print(f"snapshots: {m['ft']['snapshots']} taken, latest "
                  f"step {m['ft']['latest_step']} in {spec.ft.dir}")
        if m["final_loss"] is not None:
            print(f"final loss {m['final_loss']:.4f} "
                  f"(first {m['first_loss']:.4f})")
        if spec.obs.trace_path:
            print(f"trace written: {spec.obs.trace_path} "
                  f"(python -m repro.obs summarize "
                  f"{spec.obs.trace_path})")
        return

    data_cfg = DataConfig(vocab_size=cfg.vocab_size,
                          seq_len=spec.data.seq_len,
                          global_batch=spec.data.global_batch,
                          seed=spec.data.seed)
    with api.build_session(
            spec, verbose=True,
            checkpoint_dir=args.checkpoint_dir or None,
            save_every=args.save_every,
            resume=args.resume) as session:
        session.start()
        if args.resume:
            at = session.trainer.step_idx
            print(f"resume: {'ok, at step ' + str(at) if session.resumed else 'no checkpoint'}")
        print(f"arch={cfg.name} sync={spec.sync.mode} params="
              f"{registry.count_params(cfg):,} "
              f"loss_floor~{loss_floor(data_cfg):.3f}")
        m = session.run(args.steps)
    print(f"final loss {m['final_loss']:.4f} "
          f"(first {m['first_loss']:.4f}); mean delay "
          f"{m['mean_delay']:.2f}")
    if spec.obs.trace_path:
        print(f"trace written: {spec.obs.trace_path} "
              f"(python -m repro.obs summarize {spec.obs.trace_path})")


if __name__ == "__main__":
    main()
