"""repro — Dynamic Stale Synchronous Parallel (DSSP) distributed training in JAX.

Reproduction + TPU-pod-scale adaptation of:
  Zhao, An, Liu, Chen. "Dynamic Stale Synchronous Parallel Distributed
  Training for Deep Learning" (CS.DC 2019).

Public API surface:
  repro.api       — declarative RunSpec -> TrainingSession session layer
                    (the supported way to wire any run; start here)
  repro.core      — DSSP/SSP/ASP/BSP policies + synchronization controller
  repro.ps        — runnable parameter-server substrate (threads + simulator)
  repro.models    — model zoo (dense/MoE/SSM/hybrid/enc-dec backbones)
  repro.configs   — assigned architecture configs
  repro.launch    — mesh / dryrun / train / serve entry points
  repro.roofline  — roofline-term extraction from compiled artifacts
"""

__version__ = "1.0.0"
