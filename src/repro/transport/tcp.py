"""TCP transport: length-prefixed frames over a socket.

Framing is the ``repro.wireformat`` header itself — read exactly 44
bytes, validate, then read exactly ``payload_len`` body bytes.  There
is no resynchronization: a frame that fails header validation (bad
magic/version/length) gets an ERR reply and the connection is closed,
because a corrupt header means the byte stream's framing can no longer
be trusted.

One server thread per connection: a worker blocked in the sync-policy
gate parks its own thread, exactly like the threaded in-process
workers.  A connection that dies after HELLO without BYE (killed
worker, broken pipe mid-push) is reported to
``endpoint.on_disconnect`` so the barrier group drops it.
"""

from __future__ import annotations

import socket
import threading
from typing import Optional, Tuple

from repro.ft.backoff import CONNECT_POLICY, retry
from repro.transport.base import (
    Channel,
    PSTransportClient,
    Transport,
    TransportClosed,
)
from repro.wireformat import (
    HEADER_SIZE,
    MSG_ERR,
    MSG_HELLO,
    Frame,
    FrameError,
    decode_body,
    decode_header,
    encode_frame,
)


def _read_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    """Read exactly ``n`` bytes.  ``None`` on clean EOF at a frame
    boundary; ``FrameError`` on EOF mid-frame (the short-read case)."""
    if n == 0:
        return b""
    chunks, got = [], 0
    while got < n:
        try:
            chunk = sock.recv(min(n - got, 1 << 20))
        except OSError:
            chunk = b""
        if not chunk:
            if got == 0:
                return None
            raise FrameError(f"short read: {got} of {n} bytes")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


class TcpTransport(Transport):
    name = "tcp"

    def __init__(self, host: str = "127.0.0.1", port: int = 0):
        self._host, self._port = host, port
        self._endpoint = None
        self._listener: Optional[socket.socket] = None
        self._accept_thread: Optional[threading.Thread] = None
        self._conns: set = set()
        self._lock = threading.Lock()
        self._stopping = False

    # -- server side -----------------------------------------------------
    def serve(self, endpoint) -> None:
        self._endpoint = endpoint
        self._listener = socket.create_server((self._host, self._port))
        self._port = self._listener.getsockname()[1]
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="tcp-ps-accept", daemon=True)
        self._accept_thread.start()

    def address(self) -> Tuple:
        if self._listener is None:
            raise RuntimeError("serve() first")
        return ("tcp", self._host, self._port)

    def connect(self, worker_id: int, *,
                compress: str = "none") -> PSTransportClient:
        return connect(self.address(), worker_id, compress=compress)

    def shutdown(self) -> None:
        self._stopping = True
        if self._listener is not None:
            try:
                # close() alone is not enough: the accept thread parked
                # in accept() holds a kernel reference, so the port
                # would stay in LISTEN until a connection woke it — and
                # a same-port failover rebind would see EADDRINUSE.
                # shutdown() aborts the blocked accept immediately.
                self._listener.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                self._listener.close()
            except OSError:
                pass
        with self._lock:
            conns = list(self._conns)
        for conn in conns:
            try:
                conn.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                conn.close()
            except OSError:
                pass
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=5.0)

    def _accept_loop(self) -> None:
        while not self._stopping:
            try:
                conn, _addr = self._listener.accept()
            except OSError:
                return  # listener closed
            with self._lock:
                self._conns.add(conn)
            threading.Thread(target=self._serve_conn, args=(conn,),
                             name="tcp-ps-conn", daemon=True).start()

    def _serve_conn(self, conn: socket.socket) -> None:
        """One worker connection: frame in, endpoint call, frame out."""
        worker: Optional[int] = None
        try:
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            while True:
                header = _read_exact(conn, HEADER_SIZE)
                if header is None:
                    return  # EOF at a frame boundary
                frame, payload_len = decode_header(header)
                body = _read_exact(conn, payload_len)
                if body is None:
                    raise FrameError(
                        f"short read: 0 of {payload_len} payload bytes")
                frame = decode_body(frame, body)
                if frame.kind == MSG_HELLO:
                    worker = frame.worker
                reply = self._endpoint.handle(frame)
                conn.sendall(encode_frame(reply))
        except FrameError as e:
            try:
                conn.sendall(encode_frame(Frame(kind=MSG_ERR, error=str(e))))
            except OSError:
                pass
        except OSError:
            pass  # peer vanished mid-write
        finally:
            with self._lock:
                self._conns.discard(conn)
            try:
                conn.close()
            except OSError:
                pass
            if worker is not None and not self._stopping:
                # The connection is gone — free the worker's seat in the
                # barrier group (idempotent; a no-op after a clean BYE).
                self._endpoint.on_disconnect(worker)


class TcpChannel(Channel):
    def __init__(self, host: str, port: int, timeout: Optional[float] = None):
        self._sock = socket.create_connection((host, port), timeout=timeout)
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._sock.settimeout(None)  # pushes block in the policy gate

    def request(self, data: bytes) -> Frame:
        try:
            self._sock.sendall(data)
            header = _read_exact(self._sock, HEADER_SIZE)
            if header is None:
                raise TransportClosed("server closed the connection")
            frame, payload_len = decode_header(header)
            body = _read_exact(self._sock, payload_len)
            if body is None:
                raise TransportClosed("server closed mid-reply")
            return decode_body(frame, body)
        except OSError as e:
            raise TransportClosed(str(e)) from e

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass


def connect(address: Tuple, worker_id: int, *,
            compress: str = "none") -> PSTransportClient:
    kind, host, port = address
    if kind != "tcp":
        raise ValueError(f"not a tcp address: {address!r}")
    # Bounded connect-retry: a spawned worker routinely races the
    # server's bind (and, under failover, its restart) — ECONNREFUSED
    # here means "not yet", not "never".  TcpChannel.__init__ raises
    # plain OSError, which is exactly what the policy retries on.
    factory = lambda: TcpChannel(host, port)  # noqa: E731
    channel = retry(factory, CONNECT_POLICY, seed=worker_id)
    return PSTransportClient(channel, worker_id, compress=compress,
                             channel_factory=factory)


__all__ = ["TcpTransport", "TcpChannel", "connect"]
