"""In-process loopback transport.

The full frame path — encode, header validation, endpoint dispatch,
reply encode, decode — with no OS transport underneath.  Two jobs:

  * the uniform-API backend for the existing threaded path (a thread's
    "connection" is a direct call into the endpoint), and
  * the serialization-cost baseline in the throughput benchmark: the
    delta between ``inproc`` and ``tcp``/``shmem`` is the OS transport,
    the delta between ``inproc`` and direct ``push_packed`` calls is
    the codec.

Addresses are process-local (a token into a module registry): handing
one to a spawned worker is a usage error and raises on ``connect``.
"""

from __future__ import annotations

import itertools
import os
from typing import Dict, Tuple

from repro.transport.base import (
    Channel,
    PSTransportClient,
    Transport,
    TransportClosed,
)
from repro.wireformat import Frame, decode_frame

_REGISTRY: Dict[int, "InprocTransport"] = {}
_TOKENS = itertools.count(1)


class InprocChannel(Channel):
    def __init__(self, transport: "InprocTransport"):
        self._transport = transport

    def request(self, data: bytes) -> Frame:
        endpoint = self._transport._endpoint
        if endpoint is None or self._transport._stopping:
            raise TransportClosed("inproc transport is shut down")
        return decode_frame(endpoint.handle_bytes(data))

    def close(self) -> None:
        pass


class InprocTransport(Transport):
    name = "inproc"

    def __init__(self) -> None:
        self._endpoint = None
        self._token = next(_TOKENS)
        self._pid = os.getpid()
        self._stopping = False

    def serve(self, endpoint) -> None:
        self._endpoint = endpoint
        _REGISTRY[self._token] = self

    def address(self) -> Tuple:
        if self._endpoint is None:
            raise RuntimeError("serve() first")
        return ("inproc", self._pid, self._token)

    def connect(self, worker_id: int, *,
                compress: str = "none") -> PSTransportClient:
        return PSTransportClient(InprocChannel(self), worker_id,
                                 compress=compress)

    def shutdown(self) -> None:
        self._stopping = True
        _REGISTRY.pop(self._token, None)


def connect(address: Tuple, worker_id: int, *,
            compress: str = "none") -> PSTransportClient:
    kind, pid, token = address
    if kind != "inproc":
        raise ValueError(f"not an inproc address: {address!r}")
    if pid != os.getpid():
        raise TransportClosed(
            "inproc addresses are process-local; spawned workers need "
            "tcp or shmem")
    transport = _REGISTRY.get(token)
    if transport is None:
        raise TransportClosed(f"no live inproc transport {token}")
    return transport.connect(worker_id, compress=compress)


__all__ = ["InprocTransport", "InprocChannel", "connect"]
