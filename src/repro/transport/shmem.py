"""Shared-memory transport: per-worker request/reply slots.

Each worker owns one ``multiprocessing.shared_memory`` segment:

    offset 0        state byte (the only cross-process handshake)
    offset 8        44-byte frame header
    offset 64       frame body (lane data starts 64-byte aligned)

State machine (single-producer / single-consumer, one byte):

    0  idle             client may write a request
    1  request ready    server parses IN PLACE, handles, writes reply
    2  reply ready      client reads; the reply stays valid until the
                        client writes its NEXT request over the slot
    3  closed           server shut the segment down

The frame body is written once into the segment and parsed in place on
the server (``np.frombuffer`` over the mapped view — no intermediate
copy before the device transfer).  Both sides poll the state byte with
a short sleep: cross-process semaphores would need handle inheritance,
while a name-only address keeps ``connect()`` trivially picklable for
spawned workers.

One server thread per slot, because a push blocks inside the
sync-policy gate and must not stall other workers' slots.
"""

from __future__ import annotations

import os
import threading
import time
from multiprocessing import resource_tracker, shared_memory
from typing import List, Optional, Tuple

from repro.transport.base import (
    Channel,
    PSTransportClient,
    Transport,
    TransportClosed,
)
from repro.wireformat import (
    HEADER_SIZE,
    MSG_ERR,
    Frame,
    FrameError,
    decode_body,
    decode_header,
    encode_frame,
)

_IDLE, _REQUEST, _REPLY, _CLOSED = 0, 1, 2, 3
_HEADER_OFF = 8
_BODY_OFF = 64
_POLL_S = 0.0002


def _attach(name: str, owner_pid: int) -> shared_memory.SharedMemory:
    """Attach without letting a foreign resource tracker unlink the
    segment: before 3.13, ``SharedMemory`` registers the name even on
    attach (bpo-39959), and an *independent* process's tracker would
    destroy the server's live segment when that process exits.

    Workers spawned via ``multiprocessing`` INHERIT the owner's tracker,
    where the attach-registration is a harmless set-add dedup — and
    unregistering there would strip the owner's own registration.  So:
    unregister only when this process is neither the owner nor a
    multiprocessing child (i.e. it runs its own tracker)."""
    shm = shared_memory.SharedMemory(name=name)
    import multiprocessing as mp

    own_tracker = (os.getpid() != owner_pid
                   and mp.parent_process() is None)
    if own_tracker:
        try:
            resource_tracker.unregister(shm._name, "shared_memory")
        except Exception:  # tracker layout differs / already unregistered
            pass
    return shm


def _wait_state(buf, states, *, timeout: Optional[float] = None,
                stop=None) -> int:
    deadline = None if timeout is None else time.monotonic() + timeout
    while True:
        s = buf[0]
        if s in states:
            return s
        if s == _CLOSED:
            return s
        if stop is not None and stop():
            return _CLOSED
        if deadline is not None and time.monotonic() > deadline:
            raise TransportClosed(f"timed out waiting for state {states}")
        time.sleep(_POLL_S)


class ShmemTransport(Transport):
    """Server side: one pre-created segment per expected worker id."""

    name = "shmem"

    def __init__(self, n_workers: int, *, slack_bytes: int = 4096):
        self.n_workers = n_workers
        self.slack_bytes = slack_bytes
        self._endpoint = None
        self._segments: List[shared_memory.SharedMemory] = []
        self._threads: List[threading.Thread] = []
        self._stopping = False

    def serve(self, endpoint) -> None:
        self._endpoint = endpoint
        size = (_BODY_OFF + endpoint.max_payload_bytes()
                + self.slack_bytes)
        prefix = f"dsspw-{os.getpid()}-{os.urandom(3).hex()}"
        for w in range(self.n_workers):
            shm = shared_memory.SharedMemory(
                create=True, size=size, name=f"{prefix}-w{w}")
            shm.buf[0] = _IDLE
            self._segments.append(shm)
            t = threading.Thread(target=self._serve_slot, args=(shm, w),
                                 name=f"shmem-ps-w{w}", daemon=True)
            t.start()
            self._threads.append(t)

    def address(self) -> Tuple:
        if not self._segments:
            raise RuntimeError("serve() first")
        return ("shmem", os.getpid(),
                tuple(s.name for s in self._segments))

    def connect(self, worker_id: int, *,
                compress: str = "none") -> PSTransportClient:
        return connect(self.address(), worker_id, compress=compress)

    def shutdown(self) -> None:
        self._stopping = True
        for shm in self._segments:
            try:
                shm.buf[0] = _CLOSED
            except (ValueError, TypeError):
                pass  # already unmapped
        for t in self._threads:
            t.join(timeout=5.0)
        # A slot thread that was gate-blocked at shutdown wakes, writes
        # its final (STOP) reply and stamps _REPLY over our _CLOSED.
        # Re-stamp after the joins so a client's NEXT request fails
        # fast instead of waiting forever on a reply no thread will
        # ever write.  (A client mid-read already passed its state
        # check; header/body bytes are untouched.)
        for shm in self._segments:
            try:
                shm.buf[0] = _CLOSED
            except (ValueError, TypeError):
                pass
        # Frame payloads are views into the mapped segment; exception
        # tracebacks can park the last of them in cyclic garbage, which
        # makes mmap.close() raise BufferError until a collection runs.
        import gc

        gc.collect()
        for shm in self._segments:
            try:
                shm.close()
            except BufferError:
                continue  # a live view pins the map; the tracker reaps it
            try:
                shm.unlink()
            except FileNotFoundError:
                pass

    def _serve_slot(self, shm: shared_memory.SharedMemory,
                    slot: int) -> None:
        # NOTE: shared memory has no connection, so a killed worker is
        # invisible here (unlike tcp's EOF) — peer-death cleanup is the
        # ProcessWorkerPool's job (it reaps children and calls
        # ``endpoint.on_disconnect`` for abnormal exits).
        buf = shm.buf
        while not self._stopping:
            state = _wait_state(buf, (_REQUEST,),
                                stop=lambda: self._stopping)
            if state != _REQUEST:
                break
            try:
                frame, payload_len = decode_header(
                    bytes(buf[_HEADER_OFF:_HEADER_OFF + HEADER_SIZE]))
                if _BODY_OFF + payload_len > len(buf):
                    raise FrameError(
                        f"payload {payload_len} exceeds slot size")
                # Parse in place: no copy between the segment and the
                # server's device transfer.
                frame = decode_body(
                    frame, buf[_BODY_OFF:_BODY_OFF + payload_len])
                reply = self._endpoint.handle(frame)
            except FrameError as e:
                reply = Frame(kind=MSG_ERR, error=str(e))
            data = encode_frame(reply)
            buf[_HEADER_OFF:_HEADER_OFF + HEADER_SIZE] = data[:HEADER_SIZE]
            body = data[HEADER_SIZE:]
            if body:
                buf[_BODY_OFF:_BODY_OFF + len(body)] = body
            buf[0] = _REPLY


class ShmemChannel(Channel):
    """Client side of one slot.  Replies are parsed in place and stay
    valid until the next ``request`` on this channel (the state-machine
    contract above)."""

    def __init__(self, name: str, owner_pid: int, timeout: float = 600.0):
        try:
            self._shm = _attach(name, owner_pid)
        except FileNotFoundError as e:
            raise TransportClosed(f"no such segment {name!r}") from e
        self.timeout = timeout

    def request(self, data: bytes) -> Frame:
        buf = self._shm.buf
        state = _wait_state(buf, (_IDLE, _REPLY), timeout=self.timeout)
        if state == _CLOSED:
            raise TransportClosed("segment closed by the server")
        if _BODY_OFF + len(data) - HEADER_SIZE > len(buf):
            raise FrameError(f"frame of {len(data)} bytes exceeds the "
                             f"{len(buf)}-byte slot")
        buf[_HEADER_OFF:_HEADER_OFF + HEADER_SIZE] = data[:HEADER_SIZE]
        body = data[HEADER_SIZE:]
        if body:
            buf[_BODY_OFF:_BODY_OFF + len(body)] = body
        buf[0] = _REQUEST
        # The push gate can block the server arbitrarily long: no timeout.
        state = _wait_state(buf, (_REPLY,))
        if state == _CLOSED:
            raise TransportClosed("segment closed by the server")
        frame, payload_len = decode_header(
            bytes(buf[_HEADER_OFF:_HEADER_OFF + HEADER_SIZE]))
        return decode_body(frame, buf[_BODY_OFF:_BODY_OFF + payload_len])

    def close(self) -> None:
        # Reply payloads are parsed in place — drop any of them still
        # sitting in cyclic garbage before unmapping (see shutdown()).
        import gc

        gc.collect()
        try:
            self._shm.close()
        except (ValueError, BufferError):
            pass


def connect(address: Tuple, worker_id: int, *,
            compress: str = "none") -> PSTransportClient:
    kind, owner_pid, names = address
    if kind != "shmem":
        raise ValueError(f"not a shmem address: {address!r}")
    if not 0 <= worker_id < len(names):
        raise ValueError(f"worker {worker_id} has no slot "
                         f"(have {len(names)})")
    return PSTransportClient(ShmemChannel(names[worker_id], owner_pid),
                             worker_id, compress=compress)


__all__ = ["ShmemTransport", "ShmemChannel", "connect"]
