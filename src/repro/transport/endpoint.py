"""Server-side RPC dispatch: frames in, parameter-server calls out.

``PSServerEndpoint`` adapts a ``ParameterServer`` (``apply_mode=
'packed'``) or ``ShardedParameterServer`` (``apply_mode='fused'``) to
the frame protocol.  The endpoint is transport-agnostic: every backend
funnels each decoded request through ``handle`` (or raw bytes through
``handle_bytes``) on its own thread, so a push that blocks inside the
sync-policy gate simply parks that connection's thread — exactly the
semantics the threaded in-process workers had, now across processes.

Per-shard routing: an endpoint built with ``shards={0, 2}`` serves only
those shards' regions (frames must carry ``shard >= 0``), so different
shards of one ``ShardedParameterServer`` can live behind different
endpoints/ports.  ``ShardRouter`` is the client-side counterpart that
splits a full wire buffer across such endpoints.
"""

from __future__ import annotations

import json
import threading
from typing import Dict, Optional, Sequence

import numpy as np

from repro.transport.base import PSTransportClient
from repro.wireformat import (
    FLAG_FULL,
    WIRE_LANES,
    Frame,
    FrameError,
    MSG_BYE,
    MSG_DELTA,
    MSG_ECHO,
    MSG_ERR,
    MSG_HELLO,
    MSG_LOSS,
    MSG_OK,
    MSG_PULL,
    MSG_PULL_DELTA,
    MSG_PUSH,
    MSG_STOP,
    MSG_SUB,
    MSG_TRACE,
    decode_frame,
    encode_frame,
)


class PSServerEndpoint:
    """Frame-level RPC surface over a packed-mode parameter server.

    ``server`` must speak the packed wire format (``push_packed`` /
    ``pull_packed``); per-shard routing additionally needs the sharded
    server's ``push_packed_shard`` / ``pull_packed_shard``.
    """

    def __init__(self, server, *, shards: Optional[Sequence[int]] = None,
                 collector=None):
        # Any ParameterServerProtocol implementation works — per-shard
        # calls included (single-shard servers answer shard 0 via the
        # protocol's default impls), so no concrete-type checks here.
        if not getattr(server, "packed_wire", False):
            raise ValueError(
                "endpoint needs a packed-wire server (apply_mode="
                f"'packed'/'fused'), got apply_mode="
                f"{getattr(server, 'apply_mode', None)!r}")
        self.server = server
        #: ``repro.obs.TraceCollector`` to merge MSG_TRACE flushes into;
        #: without one the frames are acknowledged and dropped.
        self.collector = collector
        self.shards = None if shards is None else frozenset(shards)
        if self.shards is not None:
            known = range(getattr(server, "n_shards", 1))
            bad = sorted(self.shards - set(known))
            if bad:
                raise ValueError(f"endpoint routes shards {bad} but the "
                                 f"server has {len(known)} shard(s)")
        self._hello_lock = threading.Lock()
        # Serving replicas (MSG_SUB): pull-only peers that hold no
        # barrier seat, so on_disconnect must NOT remove_worker them.
        self._subscribers: set = set()
        # Pull replies re-serialize the full parameter buffer (device->
        # host) on every request; between applies that is the same
        # bytes W times per iteration.  Cache the host copy keyed by
        # (shard, reshard epoch, server version) — the version (a sum)
        # is preserved across a live reshard while the layout changes,
        # so the epoch must be part of the key for a hit to be safe.
        self._pull_lock = threading.Lock()
        self._pull_cache: Dict[int, tuple] = {}  # shard->(epoch, ver, np)

    def _epoch(self) -> int:
        """The server's live-reshard epoch (0 for servers without the
        surface) — stamped into HELLO/SUB/PULL/DELTA replies via the
        frame's otherwise-unused ``shard`` field."""
        return int(getattr(self.server, "reshard_epoch", 0))

    # -- sizing (transports pre-allocate from this) ----------------------
    def wire_rows(self) -> int:
        layout = self.server.plan.wire_layout()
        if self.shards is None:
            return layout.total_rows
        return sum(layout.shard_rows[j] for j in self.shards)

    def max_payload_bytes(self) -> int:
        layout = self.server.plan.wire_layout()
        return self.wire_rows() * WIRE_LANES * max(4, layout.dtype.itemsize)

    # -- dispatch --------------------------------------------------------
    def handle_bytes(self, data) -> bytes:
        """Raw frame in, raw reply frame out (the loopback hot path)."""
        try:
            frame = decode_frame(data)
        except FrameError as e:
            return encode_frame(Frame(kind=MSG_ERR, error=str(e)))
        return encode_frame(self.handle(frame))

    def handle(self, frame: Frame) -> Frame:
        try:
            return self._dispatch(frame)
        except Exception as e:
            # The RPC boundary must ALWAYS answer: an escaped exception
            # would kill the serving thread (tcp) or leave the slot
            # stuck in request state forever (shmem).
            return Frame(kind=MSG_ERR, worker=frame.worker,
                         error=f"{type(e).__name__}: {e}")

    def _dispatch(self, frame: Frame) -> Frame:
        server = self.server
        kind = frame.kind
        if kind == MSG_HELLO:
            with self._hello_lock:
                server.add_worker(frame.worker)  # idempotent
            return Frame(kind=MSG_OK, worker=frame.worker,
                         clock=server.version, shard=self._epoch(),
                         aux=float(self.wire_rows()))
        if kind == MSG_SUB:
            if self.shards is not None:
                raise FrameError(
                    "replica subscriptions need a full-store endpoint "
                    "(their delta pulls cover every shard); this one "
                    f"routes shards {sorted(self.shards)} only")
            with self._hello_lock:
                self._subscribers.add(frame.worker)
            # Deliberately NO add_worker: a subscriber never pushes, so
            # seating it would change every BSP/SSP/DSSP gate decision.
            return Frame(kind=MSG_OK, worker=frame.worker,
                         clock=server.version, shard=self._epoch(),
                         aux=float(self.wire_rows()))
        if kind == MSG_PULL:
            if server.stopped:
                return Frame(kind=MSG_STOP, worker=frame.worker,
                             clock=server.version)
            buf = self._pull(frame)
            return Frame(kind=MSG_OK, worker=frame.worker,
                         clock=server.version, shard=self._epoch(),
                         payload=np.asarray(buf))
        if kind == MSG_PULL_DELTA:
            if server.stopped:
                # Training workers take STOP and exit; a subscribed
                # replica still gets deltas until its vector matches
                # the FINAL weights — only then does STOP freeze it
                # (stopping earlier would pin pre-final parameters).
                with self._hello_lock:
                    is_sub = frame.worker in self._subscribers
                if not is_sub or tuple(frame.versions or ()) == \
                        tuple(server.shard_versions()):
                    return Frame(kind=MSG_STOP, worker=frame.worker,
                                 clock=server.version)
            if self.shards is not None:
                raise FrameError(
                    "delta pulls need a full-store endpoint; this one "
                    f"routes shards {sorted(self.shards)} only")
            d = server.pull_delta(frame.worker, frame.versions)
            entries = [(int(j), np.asarray(r))
                       for j, r in zip(d.shards, d.regions)]
            return Frame(kind=MSG_DELTA, worker=frame.worker,
                         clock=server.version,
                         flags=FLAG_FULL if d.full else 0,
                         shard=int(getattr(d, "epoch", 0)),
                         versions=tuple(d.versions), delta=entries)
        if kind == MSG_PUSH:
            if server.stopped:
                return Frame(kind=MSG_STOP, worker=frame.worker,
                             clock=server.version)
            self._push(frame)  # blocks in the policy gate
            kind_out = MSG_STOP if server.stopped else MSG_OK
            return Frame(kind=kind_out, worker=frame.worker,
                         clock=server.version)
        if kind == MSG_LOSS:
            server.record_loss(int(frame.clock), float(frame.aux))
            return Frame(kind=MSG_OK, worker=frame.worker,
                         clock=server.version)
        if kind == MSG_BYE:
            server.remove_worker(frame.worker)
            return Frame(kind=MSG_OK, worker=frame.worker,
                         clock=server.version)
        if kind == MSG_TRACE:
            if self.collector is not None and frame.blob:
                try:
                    events = json.loads(frame.blob)
                except json.JSONDecodeError:
                    events = None
                if isinstance(events, list):
                    self.collector.ingest(f"w{frame.worker}", events)
            return Frame(kind=MSG_OK, worker=frame.worker,
                         clock=server.version)
        # MSG_STOP is a server-side REPLY kind only: accepting it as a
        # request would let any connected worker halt training.
        if kind == MSG_ECHO:
            return Frame(kind=MSG_ECHO, worker=frame.worker,
                         payload=frame.payload)
        raise FrameError(f"kind {kind} is not a request")

    # -- server calls ----------------------------------------------------
    def _check_shard(self, frame: Frame) -> int:
        shard = frame.shard
        if self.shards is not None:
            if shard < 0:
                raise FrameError(
                    "this endpoint serves shards "
                    f"{sorted(self.shards)}; frames must carry a shard id")
            if shard not in self.shards:
                raise FrameError(f"shard {shard} is not served here "
                                 f"(have {sorted(self.shards)})")
        return shard

    def _pull(self, frame: Frame) -> np.ndarray:
        shard = self._check_shard(frame)
        epoch, version = self._epoch(), self.server.version
        with self._pull_lock:
            hit = self._pull_cache.get(shard)
            if hit is not None and hit[0] == epoch and hit[1] == version:
                return hit[2]
        if shard < 0:
            buf = self.server.pull_packed(frame.worker)
        else:
            buf = self.server.pull_packed_shard(shard, frame.worker)
        host = np.asarray(buf)
        with self._pull_lock:
            cached = self._pull_cache.get(shard)
            if cached is None or (epoch, version) >= cached[:2]:
                self._pull_cache[shard] = (epoch, version, host)
        return host

    def _push(self, frame: Frame) -> None:
        shard = self._check_shard(frame)
        if frame.payload is None:
            raise FrameError("push frame carried no payload")
        import jax.numpy as jnp  # device transfer only on the server side

        # np.array COPIES: a shmem payload is parsed in place over the
        # segment, and jnp.asarray on CPU may zero-copy alias it — the
        # async fused apply could then read bytes the client has
        # already overwritten with its next request.  (Same hazard the
        # worker loop guards with copy=True on pulls.)
        buf = jnp.asarray(np.array(frame.payload))
        if shard < 0:
            if hasattr(self.server, "reshard"):
                # Epoch-aware server: ``aux`` carries the layout epoch
                # the client packed against, so a push that raced a
                # live reshard is translated instead of rejected.
                self.server.push_packed(frame.worker, buf,
                                        epoch=int(frame.aux))
            else:
                self.server.push_packed(frame.worker, buf)
        else:
            self.server.push_packed_shard(frame.worker, shard, buf)

    # -- lifecycle hooks (called by transports) --------------------------
    def on_disconnect(self, worker: int) -> None:
        """A connection died without BYE (killed worker, broken pipe):
        drop it from the barrier group so survivors are not gated on a
        corpse — same contract as ``PSWorker``'s finally-block.
        Subscribed replicas hold no seat, so a dead replica is only
        unregistered — removing a worker id it never held would be a
        no-op, but keeping the sets separate keeps the intent loud."""
        with self._hello_lock:
            if worker in self._subscribers:
                self._subscribers.discard(worker)
                return
        self.server.remove_worker(worker)


class ShardRouter:
    """Client-side shard fan-out across per-shard endpoints.

    ``clients`` maps shard id -> ``PSTransportClient`` (several shards
    may share one client).  Pushes visit shards in canonical order
    0..S-1 — same acyclic-wait argument as the sharded server's
    ``push`` — and pulls reassemble the full wire buffer from per-shard
    regions.
    """

    def __init__(self, clients: Dict[int, PSTransportClient],
                 shard_rows: Sequence[int]):
        if sorted(clients) != list(range(len(shard_rows))):
            raise ValueError(
                f"need one client per shard 0..{len(shard_rows) - 1}, "
                f"got {sorted(clients)}")
        self.clients = dict(clients)
        self.shard_rows = tuple(shard_rows)

    def rebuild(self, clients: Dict[int, PSTransportClient],
                shard_rows: Sequence[int]) -> None:
        """Re-point the routing table after a live reshard: the shard
        count (and each shard's row extent) changed, so the old
        shard -> client map is meaningless.  Callers re-derive
        ``shard_rows`` from the NEW plan's wire layout and pass a
        client per new shard (reusing connections where the endpoint
        assignment is unchanged)."""
        if sorted(clients) != list(range(len(shard_rows))):
            raise ValueError(
                f"need one client per shard 0..{len(shard_rows) - 1}, "
                f"got {sorted(clients)}")
        self.clients = dict(clients)
        self.shard_rows = tuple(shard_rows)

    def pull_packed(self) -> Optional[np.ndarray]:
        regions = []
        for j, rows in enumerate(self.shard_rows):
            if rows == 0:
                continue
            buf = self.clients[j].pull_packed(shard=j)
            if buf is None:
                return None
            regions.append(buf)
        return np.concatenate(regions) if len(regions) > 1 else regions[0]

    def push_packed(self, wire, clock: int = 0) -> bool:
        wire = np.asarray(wire)
        if wire.shape != (sum(self.shard_rows), WIRE_LANES):
            raise ValueError(f"wire buffer {wire.shape} does not match "
                             f"({sum(self.shard_rows)}, {WIRE_LANES})")
        alive, row = True, 0
        for j, rows in enumerate(self.shard_rows):
            if rows == 0:
                continue
            region = wire[row:row + rows]
            alive = self.clients[j].push_packed(region, shard=j,
                                                clock=clock) and alive
            row += rows
        return alive
