"""Transport ABC + the worker-side RPC client.

A ``Transport`` moves *frames* (``repro.wireformat``: 44-byte header +
packed (rows, 512) body) between a worker and a ``PSServerEndpoint``.
Three backends:

  * ``inproc`` — in-memory loopback: the full encode/dispatch/decode
    path with no OS transport underneath (the existing threaded path,
    and the serialization-cost baseline for the throughput benchmark),
  * ``tcp``    — length-prefixed frames over a socket; one server
    thread per connection so a push blocked in the sync-policy gate
    never stalls other workers,
  * ``shmem``  — ``multiprocessing.shared_memory`` request/reply slots
    for local workers: the frame body is written once into the segment
    and parsed in place on the server (no intermediate buffering).

Every backend's *address* is a small picklable tuple, so a spawned
worker process can reconstruct its client with ``connect(address,
worker_id)`` — see ``repro.launch.proc_pool``.

The client side is deliberately jax-free: a worker or benchmark process
frames numpy bytes; only the jitted step itself touches jax.
"""

from __future__ import annotations

import abc
import json
from typing import Any, Callable, Optional, Sequence, Tuple

import numpy as np

from repro.api.protocol import DeltaPull
from repro.ft.backoff import RECONNECT_POLICY, BackoffPolicy, retry
from repro.obs.trace import TRACE
from repro.wireformat import (
    FLAG_FULL,
    MSG_BYE,
    MSG_DELTA,
    MSG_ECHO,
    MSG_ERR,
    MSG_HELLO,
    MSG_LOSS,
    MSG_PULL,
    MSG_PULL_DELTA,
    MSG_PUSH,
    MSG_STOP,
    MSG_SUB,
    MSG_TRACE,
    Frame,
    FrameError,
    encode_frame,
)


class TransportClosed(ConnectionError):
    """The peer went away (server shutdown, closed segment, dead socket)."""


class Channel(abc.ABC):
    """One request/reply lane between a client and an endpoint."""

    @abc.abstractmethod
    def request(self, data: bytes) -> Frame:
        """Send one encoded frame, block for the reply frame."""

    @abc.abstractmethod
    def close(self) -> None:
        """Release the lane (idempotent)."""


class PSTransportClient:
    """Parameter-server RPCs over any ``Channel``.

    Mirrors the worker-facing surface of ``ParameterServer`` /
    ``ShardedParameterServer`` (pull/push packed, record_loss, leave)
    plus an ``echo`` diagnostic.  ``push_packed``/``pull_packed``
    return ``False``/``None`` once the server has stopped — the worker
    loop's clean-exit signal.

    ``channel_factory`` (when the backend provides one — tcp does)
    arms ``reconnect()``: after the server dies mid-RPC
    (``TransportClosed`` / ``OSError``), the client rebuilds its
    channel with bounded exponential backoff and re-HELLOs.  HELLO is
    idempotent server-side, so a reconnect never acquires a second
    barrier seat; the worker keeps its last-seen version vector and
    the delta-pull dominance rule decides full-vs-delta resync.
    """

    def __init__(self, channel: Channel, worker_id: int, *,
                 compress: str = "none",
                 channel_factory: Optional[Callable[[], Channel]] = None):
        self.channel = channel
        self.worker_id = worker_id
        self.compress = compress
        self.channel_factory = channel_factory
        self.server_rows: Optional[int] = None
        self.clock = 0
        self.reconnects = 0
        #: The server's live-reshard epoch this client last built its
        #: layout against (HELLO/SUB replies carry it in the frame's
        #: ``shard`` field; old servers leave it at -1 -> treat as 0).
        #: Pushes echo it back in ``aux`` so the server can translate a
        #: buffer packed against a just-retired layout.
        self.reshard_epoch = 0

    # -- plumbing --------------------------------------------------------
    def _request(self, frame: Frame, compress: str = "none") -> Frame:
        reply = self.channel.request(encode_frame(frame, compress))
        if reply.kind == MSG_ERR:
            raise FrameError(f"server rejected frame: {reply.error}")
        self.clock = reply.clock
        return reply

    # -- RPCs ------------------------------------------------------------
    def hello(self) -> int:
        """Join the barrier group; returns the full wire-buffer row
        count (what ``pull_packed()`` with no shard routing yields)."""
        reply = self._request(Frame(kind=MSG_HELLO, worker=self.worker_id))
        self.server_rows = int(reply.aux)
        self.reshard_epoch = max(0, reply.shard)
        return self.server_rows

    def subscribe(self) -> int:
        """Register as a serving REPLICA: same reply as ``hello`` (wire
        rows in aux, server version in clock) but the server takes no
        barrier seat for us — a subscriber only ever pulls, and must
        never slow the training workers' sync-policy gate."""
        reply = self._request(Frame(kind=MSG_SUB, worker=self.worker_id))
        self.server_rows = int(reply.aux)
        self.reshard_epoch = max(0, reply.shard)
        return self.server_rows

    def pull_packed(self, shard: int = -1, *,
                    copy: bool = True) -> Optional[np.ndarray]:
        """Latest packed params (one shard's region if ``shard >= 0``);
        ``None`` once the server has stopped.

        ``copy=False`` may return a view into the transport's receive
        buffer, valid only until the next request on this client — safe
        when the caller moves it to a device buffer immediately.
        """
        reply = self._request(Frame(kind=MSG_PULL, worker=self.worker_id,
                                    shard=shard))
        if reply.kind == MSG_STOP:
            return None
        if reply.payload is None:
            raise FrameError("pull reply carried no payload")
        return np.array(reply.payload) if copy else reply.payload

    def pull_delta(self, versions, *,
                   copy: bool = True) -> Optional[DeltaPull]:
        """Version-delta pull: only the shards that advanced past
        ``versions`` (the vector returned by the previous call, or
        ``(-1,) * n_shards`` for the bootstrap pull — every shard then
        arrives, which IS the full snapshot).  Returns ``None`` once
        the server has stopped.  ``copy=False`` returns regions viewing
        the transport's receive buffer, valid until the next request on
        this client."""
        reply = self._request(Frame(kind=MSG_PULL_DELTA,
                                    worker=self.worker_id,
                                    versions=tuple(int(v)
                                                   for v in versions)))
        if reply.kind == MSG_STOP:
            return None
        if reply.kind != MSG_DELTA:
            raise FrameError(f"expected a DELTA reply, got kind "
                             f"{reply.kind}")
        entries = list(reply.delta or ())
        return DeltaPull(
            versions=tuple(reply.versions or ()),
            shards=tuple(s for s, _ in entries),
            regions=tuple(np.array(a) if copy else a
                          for _, a in entries),
            full=bool(reply.flags & FLAG_FULL),
            epoch=max(0, reply.shard))

    def push_packed(self, wire, shard: int = -1, clock: int = 0) -> bool:
        """Push a packed gradient buffer; BLOCKS until the server's sync
        policy releases this worker (the Algorithm-1 gate, carried
        across the process boundary by the pending reply).  Returns
        ``False`` once the server has stopped."""
        frame = Frame(kind=MSG_PUSH, worker=self.worker_id, shard=shard,
                      clock=clock, aux=float(self.reshard_epoch),
                      payload=np.asarray(wire))
        reply = self._request(frame, compress=self.compress)
        return reply.kind != MSG_STOP

    def record_loss(self, step: int, loss: float) -> None:
        self._request(Frame(kind=MSG_LOSS, worker=self.worker_id,
                            clock=int(step), aux=float(loss)))

    def send_trace(self, events: Sequence[dict]) -> None:
        """Flush a drained ``repro.obs`` event batch to the server-side
        collector (no-op reply; dropped silently by endpoints without
        one)."""
        if not events:
            return
        blob = json.dumps(list(events),
                          separators=(",", ":")).encode("utf-8")
        self._request(Frame(kind=MSG_TRACE, worker=self.worker_id,
                            blob=blob))

    def echo(self, arr, compress: str = "none") -> np.ndarray:
        """Payload round-trip diagnostic (health checks + codec tests)."""
        reply = self._request(Frame(kind=MSG_ECHO, worker=self.worker_id,
                                    payload=np.asarray(arr)), compress)
        return np.array(reply.payload)

    def reconnect(self, policy: BackoffPolicy = RECONNECT_POLICY, *,
                  seed: Optional[int] = None) -> int:
        """Failover path: tear down the dead channel, rebuild one via
        ``channel_factory`` with jittered backoff, and re-HELLO.

        Returns the server's wire-row count (the HELLO reply); raises
        ``TransportClosed`` when no factory exists or the backoff
        budget is exhausted — at that point the server is genuinely
        gone, not restarting.
        """
        if self.channel_factory is None:
            raise TransportClosed(
                "this transport cannot reconnect (no channel factory)")
        try:
            self.channel.close()
        except OSError:
            pass
        t0 = TRACE.now() if TRACE.enabled else 0.0
        tries = [0]

        def attempt() -> int:
            tries[0] += 1
            channel = self.channel_factory()
            try:
                self.channel = channel
                return self.hello()
            except BaseException:
                channel.close()
                raise

        rows = retry(attempt, policy,
                     seed=self.worker_id if seed is None else seed,
                     retry_on=(TransportClosed, OSError))
        self.reconnects += 1
        if TRACE.enabled:
            TRACE.span("reconnect", t0, worker=self.worker_id,
                       args={"tries": tries[0], "rows": rows})
        return rows

    def bye(self) -> None:
        """Leave the barrier group so survivors are not gated on us."""
        try:
            self._request(Frame(kind=MSG_BYE, worker=self.worker_id))
        except (TransportClosed, OSError):
            pass  # server already gone — nothing left to leave

    def close(self) -> None:
        self.channel.close()


class Transport(abc.ABC):
    """Server-side lifecycle of one transport backend."""

    name: str = "?"

    @abc.abstractmethod
    def serve(self, endpoint: Any) -> None:
        """Start accepting worker connections for ``endpoint``
        (non-blocking; serving happens on daemon threads)."""

    @abc.abstractmethod
    def address(self) -> Tuple:
        """Picklable descriptor a worker process passes to
        ``repro.transport.connect``."""

    @abc.abstractmethod
    def connect(self, worker_id: int, *,
                compress: str = "none") -> PSTransportClient:
        """In-process client (the parent's own handle on the server)."""

    @abc.abstractmethod
    def shutdown(self) -> None:
        """Stop serving and invalidate outstanding channels.  Does NOT
        stop the parameter server itself — call ``server.stop()`` first
        so gate-blocked pushes drain with a STOP reply instead of a
        broken pipe."""

    # -- context manager sugar ------------------------------------------
    def __enter__(self) -> "Transport":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
