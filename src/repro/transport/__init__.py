"""Process-boundary transports for the packed wire format.

The packed (rows, 512) buffer that PR 2 made the native push/pull
representation gets its bytes-on-the-wire story here: a ``Transport``
ABC with ``inproc`` / ``tcp`` / ``shmem`` backends, a
``PSServerEndpoint`` that serves push/pull/policy-gate RPCs for both
``ParameterServer`` and ``ShardedParameterServer`` (with per-shard
routing), and the worker-side ``PSTransportClient``.  Frame layout
lives in ``repro.wireformat``; see README.md in this directory for the
byte-level format.

Client-side imports stay jax-free so spawned worker processes can
frame bytes without paying the accelerator-runtime import.
"""

from __future__ import annotations

from typing import Tuple

from repro.transport.base import (
    Channel,
    PSTransportClient,
    Transport,
    TransportClosed,
)
from repro.transport.endpoint import PSServerEndpoint, ShardRouter
from repro.transport.inproc import InprocTransport
from repro.transport.shmem import ShmemChannel, ShmemTransport
from repro.transport.tcp import TcpChannel, TcpTransport

#: CLI surface (``train.py --transport``) and benchmark axis.
BACKENDS = ("inproc", "tcp", "shmem")


def make_transport(kind: str, *, n_workers: int = 0, host: str = "127.0.0.1",
                   port: int = 0) -> Transport:
    """Construct (but do not start) one transport backend."""
    if kind == "inproc":
        return InprocTransport()
    if kind == "tcp":
        return TcpTransport(host=host, port=port)
    if kind == "shmem":
        if n_workers < 1:
            raise ValueError("shmem needs n_workers (one slot per worker)")
        return ShmemTransport(n_workers)
    raise ValueError(f"unknown transport {kind!r} (have {BACKENDS})")


def connect(address: Tuple, worker_id: int, *,
            compress: str = "none") -> PSTransportClient:
    """Reconstruct a client from a picklable transport address — the
    entry point for spawned worker processes."""
    from repro.transport import inproc, shmem, tcp

    dispatch = {"inproc": inproc.connect, "tcp": tcp.connect,
                "shmem": shmem.connect}
    if not address or address[0] not in dispatch:
        raise ValueError(f"unknown transport address {address!r}")
    return dispatch[address[0]](address, worker_id, compress=compress)


__all__ = [
    "BACKENDS",
    "Channel",
    "InprocTransport",
    "PSServerEndpoint",
    "PSTransportClient",
    "ShardRouter",
    "ShmemChannel",
    "ShmemTransport",
    "TcpChannel",
    "TcpTransport",
    "Transport",
    "TransportClosed",
    "connect",
    "make_transport",
]
