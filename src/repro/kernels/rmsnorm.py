"""Fused RMSNorm (Pallas TPU).

Bandwidth-bound op: unfused XLA does read-x (square+mean), read-x again
(scale), write — the kernel does one HBM read of x, one write, with the
f32 reduction and the weight multiply fused in VMEM.

Grid: row blocks; BlockSpec tiles (block_rows, d) — d up to 12288 keeps
a (8, 12288) f32 tile at 393 KiB, comfortably inside VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x: jax.Array, weight: jax.Array, *, eps: float = 1e-6,
            block_rows: int = 8, interpret: bool = False) -> jax.Array:
    """x (..., d), weight (d,) -> same shape/dtype as x."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    if rows % block_rows:
        block_rows = 1
    grid = (rows // block_rows,)

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, weight)
    return out.reshape(orig_shape)
