"""Pallas TPU kernels for the compute hot-spots (validated in interpret
mode on CPU against the ref.py jnp oracles; native lowering on TPU).

  registry         enum-dispatched entry points for the worker-step ops
                   (attention / rmsnorm / residual_rmsnorm / ssm_scan),
                   selected by the ``model.kernels`` spec string
  interface        the jax-free half of the registry: KernelType enum,
                   op/variant tables, spec-string parsing
  flash_attention  causal / sliding-window / GQA, online softmax in VMEM
  rmsnorm          fused single-pass RMSNorm
  residual_rmsnorm fused residual-add + RMSNorm (pre-norm block glue)
  ssm_scan         selective scan with VMEM-resident state carry
  fused_update     DSSP delayed-gradient apply + momentum in one HBM pass
  fused_update_shard  same update over a whole PS shard's packed leaf list
                      (one pallas_call per shard instead of per leaf)
  fused_int8_ef / fused_topk_ef  wire compression + error feedback over
                      the packed (rows, 512) buffer in one VMEM pass

Models go through ``repro.kernels.registry``; the PS/compression path
goes through ``repro.kernels.ops`` (jit wrappers + custom_vjp).

Submodules load lazily (PEP 562) so that ``repro.kernels.interface``
— which the import-light spec layer uses to validate ``model.kernels``
— can be imported without pulling in jax.
"""

import importlib

_SUBMODULES = frozenset({
    "ops", "ref", "interface", "registry", "flash_attention", "rmsnorm",
    "residual_rmsnorm", "ssm_scan", "fused_update", "fused_compress",
})

#: function re-exports kept from the eager-import era (name -> submodule;
#: names that collide with a submodule resolve to the submodule above).
_FUNCS = {
    "flash_attention_fwd": "flash_attention",
    "fused_int8_ef": "fused_compress",
    "fused_topk_ef": "fused_compress",
    "fused_update_shard": "fused_update",
    "pack_shard": "fused_update",
    "unpack_shard": "fused_update",
}

__all__ = sorted(_SUBMODULES | set(_FUNCS))


def __getattr__(name):
    if name in _SUBMODULES:
        return importlib.import_module(f"{__name__}.{name}")
    if name in _FUNCS:
        mod = importlib.import_module(f"{__name__}.{_FUNCS[name]}")
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return __all__
