"""Pallas TPU kernels for the compute hot-spots (validated in interpret
mode on CPU against the ref.py jnp oracles; native lowering on TPU).

  flash_attention  causal / sliding-window / GQA, online softmax in VMEM
  rmsnorm          fused single-pass RMSNorm
  fused_update     DSSP delayed-gradient apply + momentum in one HBM pass
  fused_update_shard  same update over a whole PS shard's packed leaf list
                      (one pallas_call per shard instead of per leaf)
  fused_int8_ef / fused_topk_ef  wire compression + error feedback over
                      the packed (rows, 512) buffer in one VMEM pass

Use via repro.kernels.ops (jit wrappers + custom_vjp).
"""

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.fused_compress import fused_int8_ef, fused_topk_ef
from repro.kernels.fused_update import (fused_update, fused_update_shard,
                                        pack_shard, unpack_shard)
from repro.kernels.rmsnorm import rmsnorm

__all__ = ["ops", "ref", "flash_attention_fwd", "fused_update",
           "fused_update_shard", "pack_shard", "unpack_shard",
           "fused_int8_ef", "fused_topk_ef", "rmsnorm"]
