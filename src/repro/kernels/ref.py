"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function is the mathematical definition the kernel must reproduce;
tests sweep shapes/dtypes and assert_allclose(kernel, ref).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: Optional[int] = None) -> jax.Array:
    """q (b, lq, hq, d); k/v (b, lk, hkv, d); GQA broadcast; f32 softmax.

    Positions are aligned at the END: query i sits at absolute position
    lk - lq + i (standard for self-attention lq == lk and for decode
    suffix queries).
    """
    b, lq, hq, d = q.shape
    lk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    scores = jnp.einsum("blhd,bmhd->bhlm", qf, kf) / math.sqrt(d)
    qpos = jnp.arange(lq)[:, None] + (lk - lq)
    kpos = jnp.arange(lk)[None, :]
    mask = jnp.ones((lq, lk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhlm,bmhd->blhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def rmsnorm_ref(x: jax.Array, weight: jax.Array,
                eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * weight.astype(jnp.float32)).astype(x.dtype)


def fused_update_ref(p: jax.Array, m: jax.Array, g: jax.Array, *,
                     lr: float, beta: float,
                     scale: float = 1.0) -> Tuple[jax.Array, jax.Array]:
    """DSSP delayed-gradient apply: one fused momentum-SGD step.

        m' = beta * m + scale * g        (scale = staleness damping /
        p' = p - lr * m'                  warm-up validity gate)

    All math in f32; p'/m' cast back to the input dtypes.
    """
    mf = (beta * m.astype(jnp.float32)
          + scale * g.astype(jnp.float32))
    pf = p.astype(jnp.float32) - lr * mf
    return pf.astype(p.dtype), mf.astype(m.dtype)
