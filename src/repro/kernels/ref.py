"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function is the mathematical definition the kernel must reproduce;
tests sweep shapes/dtypes and assert_allclose(kernel, ref).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: Optional[int] = None) -> jax.Array:
    """q (b, lq, hq, d); k/v (b, lk, hkv, d); GQA broadcast; f32 softmax.

    Positions are aligned at the END: query i sits at absolute position
    lk - lq + i (standard for self-attention lq == lk and for decode
    suffix queries).
    """
    b, lq, hq, d = q.shape
    lk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    scores = jnp.einsum("blhd,bmhd->bhlm", qf, kf) / math.sqrt(d)
    qpos = jnp.arange(lq)[:, None] + (lk - lq)
    kpos = jnp.arange(lk)[None, :]
    mask = jnp.ones((lq, lk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhlm,bmhd->blhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def rmsnorm_ref(x: jax.Array, weight: jax.Array,
                eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * weight.astype(jnp.float32)).astype(x.dtype)


def residual_rmsnorm_ref(x: jax.Array, res: jax.Array, weight: jax.Array,
                         eps: float = 1e-6) -> Tuple[jax.Array, jax.Array]:
    """Oracle for ``residual_rmsnorm.residual_rmsnorm``: the pre-norm
    block glue ``s = x + res; (s, rms_norm(s) * weight)`` with the sum
    and the reduction both in f32, outputs cast back to x's dtype."""
    sf = x.astype(jnp.float32) + res.astype(jnp.float32)
    var = jnp.mean(sf * sf, axis=-1, keepdims=True)
    normed = sf * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    return sf.astype(x.dtype), normed.astype(x.dtype)


def ssm_scan_ref(u: jax.Array, delta: jax.Array, a: jax.Array,
                 bmat: jax.Array, cmat: jax.Array, h0: jax.Array,
                 ) -> Tuple[jax.Array, jax.Array]:
    """Oracle for the selective scan (Mamba S6): the literal sequential
    recurrence

        h_t = exp(delta_t * A) * h_{t-1} + delta_t * B_t * u_t
        y_t = C_t . h_t

    u/delta (b, l, di); a (di, ds); bmat/cmat (b, l, ds); h0 (b, di, ds).
    Returns (y (b, l, di) in u's dtype, h_last (b, di, ds) f32).  All
    math in f32 — this is the definition both the Pallas kernel and the
    chunked associative-scan formulation must reproduce.
    """
    uf = u.astype(jnp.float32)
    df = delta.astype(jnp.float32)
    af = a.astype(jnp.float32)
    bf = bmat.astype(jnp.float32)
    cf = cmat.astype(jnp.float32)

    def step(h, xs):
        ut, dt, bt, ct = xs                # (b, di), (b, di), (b, ds) x2
        abar = jnp.exp(dt[..., None] * af[None])           # (b, di, ds)
        bbar = dt[..., None] * bt[:, None, :] * ut[..., None]
        h = abar * h + bbar
        y = jnp.einsum("bds,bs->bd", h, ct)
        return h, y

    h_last, ys = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (uf.transpose(1, 0, 2), df.transpose(1, 0, 2),
         bf.transpose(1, 0, 2), cf.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2).astype(u.dtype), h_last


def fused_update_ref(p: jax.Array, m: jax.Array, g: jax.Array, *,
                     lr: float, beta: float,
                     scale: float = 1.0) -> Tuple[jax.Array, jax.Array]:
    """DSSP delayed-gradient apply: one fused momentum-SGD step.

        m' = beta * m + scale * g        (scale = staleness damping /
        p' = p - lr * m'                  warm-up validity gate)

    All math in f32; p'/m' cast back to the input dtypes.
    """
    mf = (beta * m.astype(jnp.float32)
          + scale * g.astype(jnp.float32))
    pf = p.astype(jnp.float32) - lr * mf
    return pf.astype(p.dtype), mf.astype(m.dtype)


def fused_update_batched_ref(p: jax.Array, m: jax.Array, gs: jax.Array, *,
                             lr: float, beta: float,
                             scales=None) -> Tuple[jax.Array, jax.Array]:
    """Oracle for ``fused_update.fused_update_batched``: K stacked
    gradients folded through momentum SEQUENTIALLY (enqueue order), each
    step casting p/m back to the storage dtype exactly like a standalone
    ``fused_update`` launch does.  This makes the batched kernel
    bitwise-identical to K sequential ``fused_update`` calls at every K
    — not merely at K=1 — which is what lets the coalesced server path
    be equivalence-tested against the uncoalesced one.
    """
    k = gs.shape[0]
    if scales is None:
        scales = (1.0,) * k
    for j in range(k):
        p, m = fused_update_ref(p, m, gs[j], lr=lr, beta=beta,
                                scale=scales[j])
    return p, m


def _per_tile(buf: jax.Array, rows: int = 8) -> jax.Array:
    """(R, 512) wire buffer -> (R//rows, rows*512) tile-major view."""
    r, lanes = buf.shape
    return buf.reshape(r // rows, rows * lanes)


def fused_int8_ef_ref(g: jax.Array, e: jax.Array
                      ) -> Tuple[jax.Array, jax.Array]:
    """Oracle for ``fused_compress.fused_int8_ef``.

    Per-(8, 512)-tile symmetric int8 quantize/dequant with error
    feedback: gf = g + e; scale = max|gf| / 127 per tile;
    g' = dequant(round(gf/scale)); e' = gf - g'.
    """
    if g.shape[0] == 0:
        return g, e
    gf = _per_tile(g.astype(jnp.float32) + e)
    scale = jnp.maximum(jnp.max(jnp.abs(gf), axis=1, keepdims=True),
                        1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127.0, 127.0)
    dq = q * scale
    return (dq.reshape(g.shape).astype(g.dtype),
            (gf - dq).reshape(g.shape))


def fused_topk_ef_ref(g: jax.Array, e: jax.Array, *,
                      fraction: float = 0.05, iters: int = 24
                      ) -> Tuple[jax.Array, jax.Array]:
    """Oracle for ``fused_compress.fused_topk_ef``: per-tile magnitude
    top-k by the same count-curve bisection the kernel unrolls, so the
    kept set matches the kernel exactly (not merely approximately)."""
    if g.shape[0] == 0:
        return g, e
    gf = _per_tile(g.astype(jnp.float32) + e)
    mag = jnp.abs(gf)
    target = jnp.float32(fraction * mag.shape[1])
    lo = jnp.zeros((mag.shape[0], 1), jnp.float32)
    hi = jnp.max(mag, axis=1, keepdims=True) + jnp.float32(1e-12)
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        keep = jnp.sum((mag >= mid).astype(jnp.float32), axis=1,
                       keepdims=True)
        take = keep >= target
        lo = jnp.where(take, mid, lo)
        hi = jnp.where(take, hi, mid)
    kept = jnp.where(mag >= lo, gf, 0.0)
    return (kept.reshape(g.shape).astype(g.dtype),
            (gf - kept).reshape(g.shape))
