"""Jit'd public wrappers around the PS-path Pallas kernels.

These wrappers fall back cleanly on every backend: off TPU the kernels
execute in ``interpret=True`` mode (same code path, CPU semantics), and
``flash_attention`` additionally drops to the jnp reference when the
sequence lengths do not divide its block size — callers never see a
TPU-only error.  On TPU the same calls compile natively.  The
flash-attention wrapper adds a ``jax.custom_vjp`` whose backward
recomputes through the jnp reference — forward-pass memory wins are the
kernel's contribution, the bwd kernel is future work (DESIGN.md §7).

The worker-step ops (attention / rmsnorm / residual_rmsnorm / ssm_scan)
have moved behind the enum-dispatched ``repro.kernels.registry``; the
wrappers here serve the server/compression path (fused update, wire
codecs) plus direct kernel experimentation.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import fused_compress as _fc
from repro.kernels import fused_update as _fu
from repro.kernels import ref as _ref
from repro.kernels import rmsnorm as _rn


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ------------------------------------------------------------ flash attn
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal: bool = True,
                    window: Optional[int] = None,
                    block: int = 128):
    lq, lk = q.shape[1], k.shape[1]
    if lq % min(block, lq) or lk % min(block, lk):
        # block does not tile the sequence: clean reference fallback
        # (same math, same vjp) instead of the kernel's grid error
        return _ref.flash_attention_ref(q, k, v, causal=causal,
                                        window=window)
    return _fa.flash_attention_fwd(q, k, v, causal=causal, window=window,
                                   block_q=min(block, lq),
                                   block_k=min(block, lk),
                                   interpret=not on_tpu())


def _fa_fwd(q, k, v, causal, window, block):
    out = flash_attention(q, k, v, causal, window, block)
    return out, (q, k, v)


def _fa_bwd(causal, window, block, res, dout):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: _ref.flash_attention_ref(
            q_, k_, v_, causal=causal, window=window), q, k, v)
    return vjp(dout)


flash_attention.defvjp(_fa_fwd, _fa_bwd)


# ------------------------------------------------------------ rmsnorm
def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    return _rn.rmsnorm(x, weight, eps=eps, interpret=not on_tpu())


# ------------------------------------------------------------ fused update
def fused_update(p: jax.Array, m: jax.Array, g: jax.Array, *,
                 lr, beta: float = 0.9, scale=1.0,
                 ) -> Tuple[jax.Array, jax.Array]:
    return _fu.fused_update(p, m, g, lr=lr, beta=beta, scale=scale,
                            interpret=not on_tpu())


def fused_update_batched(p, m, gs, *, lr, beta: float = 0.9, scales=None):
    """Coalesced apply: K stacked gradient buffers folded through
    momentum in ONE pallas_call, bitwise-identical to K sequential
    ``fused_update`` calls in stack order (see kernels/fused_update)."""
    return _fu.fused_update_batched(p, m, gs, lr=lr, beta=beta,
                                    scales=scales, interpret=not on_tpu())


def fused_update_shard(ps, ms, gs, *, lr, beta: float = 0.9, scale=1.0):
    """Batched shard apply: all leaves through ONE pallas_call (packed
    (rows, 512) layout) — the sharded PS's per-shard update kernel."""
    return _fu.fused_update_shard(ps, ms, gs, lr=lr, beta=beta, scale=scale,
                                  interpret=not on_tpu())


def fused_int8_ef(g, err):
    """Fused int8 quantize+dequant+error-feedback over a packed wire
    buffer — ONE kernel launch per shard (see kernels/fused_compress)."""
    return _fc.fused_int8_ef(g, err, interpret=not on_tpu())


def fused_topk_ef(g, err, *, fraction: float = 0.05):
    """Fused per-tile magnitude top-k + error feedback on the wire."""
    return _fc.fused_topk_ef(g, err, fraction=fraction,
                             interpret=not on_tpu())


def fused_update_tree(params, momenta, grads, *, lr, beta: float = 0.9,
                      scale=1.0):
    """Tree-mapped fused update (the DSSP pipeline's apply phase)."""
    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_m = jax.tree_util.tree_leaves(momenta)
    flat_g = jax.tree_util.tree_leaves(grads)
    outs = [fused_update(p, m, g, lr=lr, beta=beta, scale=scale)
            for p, m, g in zip(flat_p, flat_m, flat_g)]
    return (tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]))
