"""Selective-scan (Mamba S6) recurrence as a Pallas TPU kernel.

    h_t = exp(delta_t * A) * h_{t-1} + delta_t * B_t * u_t
    y_t = C_t . h_t

The XLA formulations materialize the discretized (l, d_inner, d_state)
Abar/Bbar tensors in HBM before scanning them; this kernel streams one
chunk of (u, delta, B, C) into VMEM, discretizes per-timestep on the
fly, and carries the (d_inner, d_state) hidden state in a VMEM scratch
across chunk steps — the state tensor never round-trips HBM and the
per-step working set is O(d_inner * d_state) instead of
O(l * d_inner * d_state).

Grid: (batch, l / chunk) with the chunk axis innermost — TPU executes
it sequentially, which is exactly the dependence order of the scan (and
interpret mode preserves the same order on CPU).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_scan_kernel(u_ref, delta_ref, a_ref, b_ref, c_ref, h0_ref,
                     y_ref, hlast_ref, h_ref, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)                  # (di, ds)

    def step(t, h):
        dt = pl.load(delta_ref, (pl.ds(t, 1), slice(None))
                     ).astype(jnp.float32).reshape(-1, 1)        # (di, 1)
        ut = pl.load(u_ref, (pl.ds(t, 1), slice(None))
                     ).astype(jnp.float32).reshape(-1, 1)        # (di, 1)
        bt = pl.load(b_ref, (pl.ds(t, 1), slice(None))
                     ).astype(jnp.float32).reshape(1, -1)        # (1, ds)
        ct = pl.load(c_ref, (pl.ds(t, 1), slice(None))
                     ).astype(jnp.float32).reshape(1, -1)        # (1, ds)
        h = jnp.exp(dt * a) * h + dt * bt * ut          # (di, ds)
        yt = jnp.sum(h * ct, axis=1)                    # (di,)
        pl.store(y_ref, (pl.ds(t, 1), slice(None)),
                 yt.reshape(1, -1).astype(y_ref.dtype))
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h
    hlast_ref[...] = h.astype(hlast_ref.dtype)


def ssm_scan(u: jax.Array, delta: jax.Array, a: jax.Array,
             bmat: jax.Array, cmat: jax.Array, h0: jax.Array, *,
             chunk: int = 128, interpret: bool = False
             ) -> Tuple[jax.Array, jax.Array]:
    """u/delta (b, l, di); a (di, ds); bmat/cmat (b, l, ds);
    h0 (b, di, ds) -> (y (b, l, di) in u's dtype, h_last (b, di, ds) f32).
    """
    b, l, di = u.shape
    ds = a.shape[-1]
    chunk = min(chunk, l) if chunk > 0 else l
    if l % chunk:
        chunk = l
    grid = (b, l // chunk)

    y, h_last = pl.pallas_call(
        functools.partial(_ssm_scan_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, chunk, di), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((None, chunk, di), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((di, ds), lambda bi, ci: (0, 0)),
            pl.BlockSpec((None, chunk, ds), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((None, chunk, ds), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((None, di, ds), lambda bi, ci: (bi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, chunk, di), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((None, di, ds), lambda bi, ci: (bi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, l, di), u.dtype),
            jax.ShapeDtypeStruct((b, di, ds), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((di, ds), jnp.float32),   # carried hidden state
        ],
        interpret=interpret,
    )(u, delta, a, bmat, cmat, h0)
    return y, h_last
