"""Flash attention for TPU (Pallas): causal / sliding-window / GQA.

TPU-native adaptation of the flash-attention insight: the (lq × lk)
score matrix never touches HBM.  Blocking is chosen for the TPU memory
hierarchy — (block_q × d) query tiles and (block_k × d) key/value tiles
stream HBM→VMEM, the (block_q × block_k) score tile lives only in VMEM,
and both matmuls hit the MXU with 128-aligned dims.  Online softmax
(running max m, normalizer l, accumulator acc in VMEM scratch) carries
across the innermost grid dimension, which TPU executes sequentially.

Grid: (batch, q_heads, lq/block_q, lk/block_k) — the kv-block axis is
innermost; GQA maps q-head h to kv-head h // (hq // hkv) in the K/V
index_map (no materialized head broadcast).

The kernel is forward-only; ``ops.flash_attention`` wraps it in a
``jax.custom_vjp`` whose backward recomputes through the jnp reference
(flash-bwd kernel is listed as future work in DESIGN.md §7).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, block_q: int, block_k: int,
                  causal: bool, window: Optional[int],
                  lq: int, lk: int):
    """One (q-block, k-block) step of online-softmax attention.

    Refs (VMEM):
      q_ref (block_q, d), k_ref/v_ref (block_k, d), o_ref (block_q, d)
      m_ref/l_ref (block_q,) f32 scratch, acc_ref (block_q, d) f32 scratch
    """
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # absolute positions (queries aligned at the end: pos = lk - lq + i)
    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + (lk - lq)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    # skip fully-masked blocks (causal: k entirely in the future;
    # window: k entirely before the window)
    block_needed = True
    if causal:
        block_needed = (ki * block_k) <= (qi * block_q + block_q - 1
                                          + (lk - lq))
    if window is not None:
        first_valid = qi * block_q + (lk - lq) - window + 1
        block_needed = jnp.logical_and(
            block_needed, (ki * block_k + block_k - 1) >= first_valid)

    @pl.when(block_needed)
    def _body():
        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        v = v_ref[...].astype(jnp.float32)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(3) - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: Optional[int] = None,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False) -> jax.Array:
    """q (b, lq, hq, d); k/v (b, lk, hkv, d) -> (b, lq, hq, d)."""
    b, lq, hq, d = q.shape
    lk, hkv = k.shape[1], k.shape[2]
    if hq % hkv:
        raise ValueError("hq must be a multiple of hkv")
    group = hq // hkv
    block_q = min(block_q, lq)
    block_k = min(block_k, lk)
    if lq % block_q or lk % block_k:
        raise ValueError(f"seq lens ({lq},{lk}) must divide blocks "
                         f"({block_q},{block_k})")
    grid = (b, hq, lq // block_q, lk // block_k)

    kernel = functools.partial(
        _flash_kernel, scale=1.0 / math.sqrt(d), block_q=block_q,
        block_k=block_k, causal=causal, window=window, lq=lq, lk=lk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, None, d),
                         lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
            pl.BlockSpec((None, block_k, None, d),
                         lambda bi, hi, qi, ki: (bi, ki, hi // group, 0)),
            pl.BlockSpec((None, block_k, None, d),
                         lambda bi, hi, qi, ki: (bi, ki, hi // group, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, None, d),
                               lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, lq, hq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),    # running max m
            pltpu.VMEM((block_q,), jnp.float32),    # normalizer l
            pltpu.VMEM((block_q, d), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
