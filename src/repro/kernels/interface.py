"""Kernel dispatch interface: ops, variants, and spec-string parsing.

This module is the jax-free half of the kernel registry (in the style
of ddrous/mamba-jax's ``KernelType`` interface): it defines WHICH hot
ops exist, WHICH variants each op implements, and how the validated
``model.kernels`` spec string maps onto them.  The jax-heavy half —
the actual enum-dispatched implementations with their ``custom_vjp``
pairings — lives in ``repro.kernels.registry``.

The spec-string grammar (the ``model.kernels`` knob):

    "auto"                          per-backend default for every op
    "pallas" / "xla"                one variant for every op
    "attention=pallas,ssm_scan=xla_associative"
                                    per-op overrides (unlisted ops stay
                                    on the global default, "auto" unless
                                    a bare token set one)
    "xla,ssm_scan=pallas"           bare token + overrides compose

``"auto"`` resolves per backend: every op takes its Pallas kernel on
TPU; off-TPU the XLA variants win (Pallas interpret mode is a
correctness harness, not a fast path), with ``ssm_scan`` taking the
chunked associative scan — the historical model code paths exactly.

Importing this module never imports jax, so the spec layer
(``repro.api.spec``) can validate ``model.kernels`` in its
import-light ``--dump-schema`` world.
"""

from __future__ import annotations

import enum
from typing import Dict


class KernelType(enum.Enum):
    PALLAS = 0              # Pallas kernel (interpret=True off-TPU)
    XLA = 1                 # plain-jnp reference implementation
    XLA_ASSOCIATIVE = 2     # associative-scan formulation (ssm_scan)


#: spec-string token -> enum member.
KernelTypeMapping: Dict[str, KernelType] = {
    "pallas": KernelType.PALLAS,
    "xla": KernelType.XLA,
    "xla_associative": KernelType.XLA_ASSOCIATIVE,
}

AUTO = "auto"

#: Registry surface: op name -> the variant tokens it implements.
OPS: Dict[str, tuple] = {
    "attention": ("pallas", "xla"),
    "rmsnorm": ("pallas", "xla"),
    "residual_rmsnorm": ("pallas", "xla"),
    "ssm_scan": ("pallas", "xla", "xla_associative"),
}

#: "auto" resolution per backend.  TPU: Pallas everywhere (the native
#: lowerings).  Anything else: the XLA formulations the models always
#: ran (interpret-mode Pallas stays a test/bench harness off-TPU).
_AUTO_TPU: Dict[str, str] = {op: "pallas" for op in OPS}
_AUTO_OTHER: Dict[str, str] = {
    "attention": "xla",
    "rmsnorm": "xla",
    "residual_rmsnorm": "xla",
    "ssm_scan": "xla_associative",
}


def valid_overrides() -> str:
    """Human-readable per-op override table for error messages."""
    return ", ".join(f"{op}={{{'|'.join(vs)}}}" for op, vs in OPS.items())


def parse_kernels(spec: str) -> Dict[str, str]:
    """Parse a ``model.kernels`` string into {op: variant-or-'auto'}.

    Returns a FULL mapping (every op present).  Raises ``ValueError``
    with a message listing the valid per-op overrides on any unknown
    op, unknown variant, or a variant an op does not implement.
    """
    if not isinstance(spec, str) or not spec.strip():
        raise ValueError(
            "model.kernels must be a non-empty string: 'auto', a "
            f"variant ({'/'.join(KernelTypeMapping)}), or per-op "
            f"overrides ({valid_overrides()})")
    chosen = {op: AUTO for op in OPS}
    for token in spec.split(","):
        token = token.strip()
        if not token:
            raise ValueError(
                f"model.kernels={spec!r} has an empty entry; valid "
                f"per-op overrides: {valid_overrides()}")
        if "=" not in token:
            if token != AUTO and token not in KernelTypeMapping:
                raise ValueError(
                    f"model.kernels variant {token!r} is unknown; use "
                    f"'auto', {'/'.join(KernelTypeMapping)}, or per-op "
                    f"overrides ({valid_overrides()})")
            for op, variants in OPS.items():
                if token == AUTO or token in variants:
                    chosen[op] = token
                else:
                    raise ValueError(
                        f"model.kernels={token!r} does not apply to "
                        f"every op ({op} implements only "
                        f"{'/'.join(variants)}); use per-op overrides: "
                        f"{valid_overrides()}")
            continue
        op, _, variant = token.partition("=")
        op, variant = op.strip(), variant.strip()
        if op not in OPS:
            raise ValueError(
                f"model.kernels names unknown op {op!r}; valid per-op "
                f"overrides: {valid_overrides()}")
        if variant != AUTO and variant not in OPS[op]:
            raise ValueError(
                f"model.kernels: op {op!r} has no variant {variant!r} "
                f"(it implements {'/'.join(OPS[op])}); valid per-op "
                f"overrides: {valid_overrides()}")
        chosen[op] = variant
    return chosen


def resolve(spec: str, op: str, *, tpu: bool) -> KernelType:
    """The variant a spec string selects for ``op`` on this backend."""
    if op not in OPS:
        raise ValueError(f"unknown registry op {op!r}; registry ops: "
                         f"{sorted(OPS)}")
    variant = parse_kernels(spec)[op]
    if variant == AUTO:
        variant = (_AUTO_TPU if tpu else _AUTO_OTHER)[op]
    return KernelTypeMapping[variant]
