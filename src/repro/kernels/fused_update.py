"""Fused DSSP delayed-gradient apply (Pallas TPU).

The optimizer update is the op DSSP itself makes hot: every step reads
the delayed gradient out of the ring buffer, folds it into momentum and
applies it (arithmetic intensity ~0.25 flop/byte — purely HBM-bound).
Unfused XLA issues separate read/write passes for the momentum update
and the parameter update; this kernel streams p, m, g through VMEM once:

    m' = beta * m + scale * g        (scale = staleness damping * warm-up
    p' = p - lr * m'                  validity from the DSSP pipeline)

4 HBM transfers per element (read p, m, g; write p', m' aliased over p,
m) instead of 6 — a 1.5x traffic cut on the dominant term of the update
phase.  ``scale`` and ``lr`` arrive in SMEM as scalar-prefetch-style
(1, 1) operands so the controller can re-tune them without recompiling.

Tiles: (8, 512) f32 — lane-dim multiple of 128, 16 KiB per operand tile.
"""

from __future__ import annotations

import functools
import math
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.obs.trace import TRACE
from repro.perfcount import WIRE
from repro.wireformat import WIRE_LANES as _LANES
from repro.wireformat import WIRE_ROWS as _ROWS
from repro.wireformat import pack_flat, resolve_wire_dtype


def _fused_update_kernel(scalars_ref, p_ref, m_ref, g_ref,
                         po_ref, mo_ref, *, beta: float):
    lr = scalars_ref[0, 0]
    scale = scalars_ref[0, 1]
    m = (beta * m_ref[...].astype(jnp.float32)
         + scale * g_ref[...].astype(jnp.float32))
    po_ref[...] = (p_ref[...].astype(jnp.float32)
                   - lr * m).astype(po_ref.dtype)
    mo_ref[...] = m.astype(mo_ref.dtype)


def fused_update(p: jax.Array, m: jax.Array, g: jax.Array, *,
                 lr, beta: float = 0.9, scale=1.0,
                 interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """One fused momentum step on an arbitrary-shaped leaf.

    Returns (p', m') with the input dtypes.  lr/scale may be python
    floats or traced scalars (no recompile on change).
    """
    WIRE.pallas_calls += 1
    if TRACE.enabled:
        TRACE.instant("kernel_launch", args={"kernel": "fused_update"})
    orig_shape = p.shape
    n = p.size
    tile = _ROWS * _LANES
    pad = (-n) % tile
    if pad:
        p2 = jnp.pad(p.reshape(-1), (0, pad))
        m2 = jnp.pad(m.reshape(-1), (0, pad))
        g2 = jnp.pad(g.reshape(-1), (0, pad))
    else:
        p2, m2, g2 = p.reshape(-1), m.reshape(-1), g.reshape(-1)
    rows = (n + pad) // _LANES
    p2 = p2.reshape(rows, _LANES)
    m2 = m2.reshape(rows, _LANES)
    g2 = g2.reshape(rows, _LANES)
    scalars = jnp.array([[lr, scale]], jnp.float32)
    grid = (rows // _ROWS,)

    spec = pl.BlockSpec((_ROWS, _LANES), lambda i: (i, 0))
    po, mo = pl.pallas_call(
        functools.partial(_fused_update_kernel, beta=beta),
        grid=grid,
        in_specs=[pl.BlockSpec((1, 2), lambda i: (0, 0)), spec, spec, spec],
        out_specs=(spec, spec),
        out_shape=(jax.ShapeDtypeStruct((rows, _LANES), p.dtype),
                   jax.ShapeDtypeStruct((rows, _LANES), m.dtype)),
        interpret=interpret,
    )(scalars, p2, m2, g2)
    po = po.reshape(-1)[:n].reshape(orig_shape)
    mo = mo.reshape(-1)[:n].reshape(orig_shape)
    return po, mo


# ----------------------------------------------------- contributor batching
# The coalesced server apply: K workers' gradient buffers for the SAME
# parameter region, folded in one launch.  p and m stream through VMEM
# once regardless of K; only the gradient traffic scales with the number
# of contributors — (2 + K) reads + 2 writes per element instead of the
# 3K + 2K a sequence of ``fused_update`` launches costs.
#
# The fold is SEQUENTIAL inside the kernel (contributor 0 first, each
# step rounding p/m to the storage precision exactly as a standalone
# launch's store + reload would), so the result matches K back-to-back
# ``fused_update`` calls in enqueue order — bitwise for f32 state (the
# equivalence tests assert it) and K=1 for every dtype (dispatched to
# the standalone kernel outright); narrow-dtype folds at K > 1 may
# differ by 1 ulp where XLA picks a different FMA contraction around
# the in-register rounding.  Coalescing changes launch count, not
# semantics.  K is static: one compilation per distinct window fill
# (bounded by the coalesce knob).

def _round_to(x: jax.Array, dtype) -> jax.Array:
    """Round an f32 value to ``dtype``'s precision WITHOUT leaving f32.

    An ``astype(dtype).astype(f32)`` round-trip inside one fused
    computation is elided by XLA's excess-precision rule, which would
    make the batched fold drift (by 1 ulp) from K sequential launches
    that physically store the narrow dtype between steps.
    ``lax.reduce_precision`` is the documented non-elidable rounding.
    """
    if jnp.dtype(dtype) == jnp.float32:
        return x
    fi = jnp.finfo(dtype)
    return jax.lax.reduce_precision(x, fi.nexp, fi.nmant)


def _fused_update_batched_kernel(scalars_ref, p_ref, m_ref, g_ref,
                                 po_ref, mo_ref, *, beta: float, k: int):
    lr = scalars_ref[0, 0]
    p = p_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    for j in range(k):          # unrolled at trace time (k is static)
        scale = scalars_ref[0, 1 + j]
        # A standalone launch updates p with the UNROUNDED f32 momentum
        # and narrows only at the store; the carried values round like a
        # store + reload.  Mirror that exactly.
        mf = beta * m + scale * g_ref[j].astype(jnp.float32)
        p = _round_to(p - lr * mf, po_ref.dtype)
        m = _round_to(mf, mo_ref.dtype)
    po_ref[...] = p.astype(po_ref.dtype)
    mo_ref[...] = m.astype(mo_ref.dtype)


def fused_update_batched(p: jax.Array, m: jax.Array, gs: jax.Array, *,
                         lr, beta: float = 0.9, scales=None,
                         interpret: bool = False,
                         ) -> Tuple[jax.Array, jax.Array]:
    """One fused momentum fold of K stacked gradients into (p, m).

    ``gs`` has shape ``(K,) + p.shape`` (one stacked buffer per
    contributor); ``scales`` is a length-K sequence of per-contributor
    step scales (staleness damping), python floats or traced scalars.
    Returns (p', m') with the input dtypes, bitwise-identical to K
    sequential ``fused_update(p, m, gs[j], scale=scales[j])`` calls.
    """
    k = gs.shape[0]
    if gs.shape[1:] != p.shape:
        raise ValueError(f"stacked grads {gs.shape} do not match "
                         f"parameter shape {p.shape}")
    if scales is None:
        scales = (1.0,) * k
    if len(scales) != k:
        raise ValueError(f"{len(scales)} scales for {k} stacked grads")
    if k == 1:
        # The standalone kernel IS the K=1 fold — dispatching to it
        # makes a window of one bitwise-trivially identical to the
        # uncoalesced path for every dtype.
        return fused_update(p, m, gs[0], lr=lr, beta=beta,
                            scale=scales[0], interpret=interpret)
    WIRE.pallas_calls += 1
    if TRACE.enabled:
        TRACE.instant("kernel_launch", args={"kernel": "fused_update_batched"})
    orig_shape = p.shape
    n = p.size
    tile = _ROWS * _LANES
    pad = (-n) % tile
    if pad:
        p2 = jnp.pad(p.reshape(-1), (0, pad))
        m2 = jnp.pad(m.reshape(-1), (0, pad))
        g2 = jnp.pad(gs.reshape(k, -1), ((0, 0), (0, pad)))
    else:
        p2, m2, g2 = p.reshape(-1), m.reshape(-1), gs.reshape(k, -1)
    rows = (n + pad) // _LANES
    p2 = p2.reshape(rows, _LANES)
    m2 = m2.reshape(rows, _LANES)
    g2 = g2.reshape(k, rows, _LANES)
    scalars = jnp.stack(
        [jnp.asarray(lr, jnp.float32)]
        + [jnp.asarray(s, jnp.float32) for s in scales]).reshape(1, 1 + k)
    grid = (rows // _ROWS,)

    spec = pl.BlockSpec((_ROWS, _LANES), lambda i: (i, 0))
    gspec = pl.BlockSpec((k, _ROWS, _LANES), lambda i: (0, i, 0))
    po, mo = pl.pallas_call(
        functools.partial(_fused_update_batched_kernel, beta=beta, k=k),
        grid=grid,
        in_specs=[pl.BlockSpec((1, 1 + k), lambda i: (0, 0)),
                  spec, spec, gspec],
        out_specs=(spec, spec),
        out_shape=(jax.ShapeDtypeStruct((rows, _LANES), p.dtype),
                   jax.ShapeDtypeStruct((rows, _LANES), m.dtype)),
        interpret=interpret,
    )(scalars, p2, m2, g2)
    po = po.reshape(-1)[:n].reshape(orig_shape)
    mo = mo.reshape(-1)[:n].reshape(orig_shape)
    return po, mo


# ---------------------------------------------------------- shard batching
# A parameter-server shard holds many small leaves (slices of the model's
# pytree).  Calling ``fused_update`` per leaf issues one ``pallas_call``
# per leaf — grid-launch overhead dominates for the tail of small tensors.
# Instead the shard's leaves are packed once into a single (rows, 512)
# buffer and the WHOLE shard updates in one kernel launch; momentum can
# stay resident in the packed layout between steps (see
# ``repro.ps.sharded.server``).

def pack_shard(leaves: Sequence[jax.Array], dtype=None) -> jax.Array:
    """Flatten + concatenate leaves into one lane-aligned (rows, 512) buffer.

    ``dtype=None`` (default) preserves a uniform leaf dtype on the wire
    — bf16 leaves pack into a bf16 buffer and round-trip bitwise through
    ``unpack_shard`` instead of silently bouncing through f32 (which
    would also flip the fused apply's *persistent* accumulation dtype to
    f32 while the tree path accumulates in the leaf dtype).  Mixed-dtype
    leaf lists are explicitly promoted to f32; pass ``dtype=`` to force
    a wire dtype.
    """
    if not leaves:
        return jnp.zeros((0, _LANES), dtype or jnp.float32)
    if dtype is None:
        dtype = resolve_wire_dtype((jnp.dtype(x.dtype) for x in leaves),
                                   default=jnp.dtype(jnp.float32))
    return pack_flat(leaves, dtype)


def unpack_shard(buf: jax.Array, shapes: Sequence[Tuple[int, ...]],
                 dtypes: Sequence) -> List[jax.Array]:
    """Inverse of ``pack_shard`` given the original leaf shapes/dtypes.

    Casts only when the buffer dtype differs from a leaf's dtype (a
    uniform-dtype shard never round-trips through another precision).
    """
    WIRE.unpacks += 1
    flat = buf.reshape(-1)
    out: List[jax.Array] = []
    off = 0
    for shape, dt in zip(shapes, dtypes):
        size = math.prod(shape) if shape else 1
        out.append(flat[off:off + size].reshape(shape).astype(dt))
        off += size
    return out


def fused_update_shard(ps: Sequence[jax.Array], ms: Sequence[jax.Array],
                       gs: Sequence[jax.Array], *, lr, beta: float = 0.9,
                       scale=1.0, interpret: bool = False,
                       ) -> Tuple[List[jax.Array], List[jax.Array]]:
    """One fused momentum step over a whole shard's leaf list.

    Packs (p, m, g) into three (rows, 512) buffers, runs a single
    ``pallas_call`` over the concatenation, and unpacks back to the input
    shapes/dtypes.  Numerically identical to per-leaf ``fused_update``
    (the kernel is elementwise).
    """
    if len(ps) != len(ms) or len(ps) != len(gs):
        raise ValueError("p/m/g leaf lists must align")
    if not ps:
        return [], []
    shapes = [p.shape for p in ps]
    p_dtypes = [p.dtype for p in ps]
    m_dtypes = [m.dtype for m in ms]
    po, mo = fused_update(pack_shard(ps), pack_shard(ms), pack_shard(gs),
                          lr=lr, beta=beta, scale=scale,
                          interpret=interpret)
    return (unpack_shard(po, shapes, p_dtypes),
            unpack_shard(mo, shapes, m_dtypes))
