"""Fused DSSP delayed-gradient apply (Pallas TPU).

The optimizer update is the op DSSP itself makes hot: every step reads
the delayed gradient out of the ring buffer, folds it into momentum and
applies it (arithmetic intensity ~0.25 flop/byte — purely HBM-bound).
Unfused XLA issues separate read/write passes for the momentum update
and the parameter update; this kernel streams p, m, g through VMEM once:

    m' = beta * m + scale * g        (scale = staleness damping * warm-up
    p' = p - lr * m'                  validity from the DSSP pipeline)

4 HBM transfers per element (read p, m, g; write p', m' aliased over p,
m) instead of 6 — a 1.5x traffic cut on the dominant term of the update
phase.  ``scale`` and ``lr`` arrive in SMEM as scalar-prefetch-style
(1, 1) operands so the controller can re-tune them without recompiling.

Tiles: (8, 512) f32 — lane-dim multiple of 128, 16 KiB per operand tile.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANES = 512
_ROWS = 8


def _fused_update_kernel(scalars_ref, p_ref, m_ref, g_ref,
                         po_ref, mo_ref, *, beta: float):
    lr = scalars_ref[0, 0]
    scale = scalars_ref[0, 1]
    m = (beta * m_ref[...].astype(jnp.float32)
         + scale * g_ref[...].astype(jnp.float32))
    po_ref[...] = (p_ref[...].astype(jnp.float32)
                   - lr * m).astype(po_ref.dtype)
    mo_ref[...] = m.astype(mo_ref.dtype)


def fused_update(p: jax.Array, m: jax.Array, g: jax.Array, *,
                 lr, beta: float = 0.9, scale=1.0,
                 interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """One fused momentum step on an arbitrary-shaped leaf.

    Returns (p', m') with the input dtypes.  lr/scale may be python
    floats or traced scalars (no recompile on change).
    """
    orig_shape = p.shape
    n = p.size
    tile = _ROWS * _LANES
    pad = (-n) % tile
    if pad:
        p2 = jnp.pad(p.reshape(-1), (0, pad))
        m2 = jnp.pad(m.reshape(-1), (0, pad))
        g2 = jnp.pad(g.reshape(-1), (0, pad))
    else:
        p2, m2, g2 = p.reshape(-1), m.reshape(-1), g.reshape(-1)
    rows = (n + pad) // _LANES
    p2 = p2.reshape(rows, _LANES)
    m2 = m2.reshape(rows, _LANES)
    g2 = g2.reshape(rows, _LANES)
    scalars = jnp.array([[lr, scale]], jnp.float32)
    grid = (rows // _ROWS,)

    spec = pl.BlockSpec((_ROWS, _LANES), lambda i: (i, 0))
    po, mo = pl.pallas_call(
        functools.partial(_fused_update_kernel, beta=beta),
        grid=grid,
        in_specs=[pl.BlockSpec((1, 2), lambda i: (0, 0)), spec, spec, spec],
        out_specs=(spec, spec),
        out_shape=(jax.ShapeDtypeStruct((rows, _LANES), p.dtype),
                   jax.ShapeDtypeStruct((rows, _LANES), m.dtype)),
        interpret=interpret,
    )(scalars, p2, m2, g2)
    po = po.reshape(-1)[:n].reshape(orig_shape)
    mo = mo.reshape(-1)[:n].reshape(orig_shape)
    return po, mo
