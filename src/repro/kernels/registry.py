"""Enum-dispatched kernel registry: ONE call site per worker-step hot op.

Each public function here is the single entry point the models call for
its op — ``attention``, ``rmsnorm``, ``residual_rmsnorm``, ``ssm_scan``
— dispatched over ``KernelType`` variants (``repro.kernels.interface``)
by the validated ``model.kernels`` spec string:

    variant           what runs
    ----------------  -------------------------------------------------
    PALLAS            the Pallas kernel (native on TPU, interpret=True
                      everywhere else), wrapped in ``jax.custom_vjp``
                      whose backward recomputes through the matching
                      ``kernels/ref.py`` oracle
    XLA               the jnp reference formulation (native autodiff) —
                      bit-identical to the oracle by construction
    XLA_ASSOCIATIVE   ssm_scan only: the chunked associative-scan
                      formulation (parallel within chunks, lax.scan
                      carry across) that ``models/ssm.py`` historically
                      inlined

Dispatch is resolved at trace time (the spec string and backend are
static), so a jitted step compiles exactly one variant per op.  The
``kernels/ref.py`` oracles stay the correctness contract for every
variant: tests/test_kernels.py sweeps the full (op, variant, dtype)
grid fwd AND bwd against them.

Fallback behavior is part of the contract: the PALLAS attention variant
requires a block size from ``_BLOCKS`` to divide both sequence lengths
(the flash kernel's grid constraint) and otherwise falls back to the
XLA formulation — never an error, never a silent wrong answer.
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import ref as _ref
from repro.kernels import residual_rmsnorm as _rrn
from repro.kernels import rmsnorm as _rn
from repro.kernels import ssm_scan as _scan
from repro.kernels.interface import AUTO, KernelType, resolve


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolved(op: str, kernels: str = AUTO) -> KernelType:
    """The variant a spec string picks for ``op`` on the live backend
    (what a jitted step will actually compile).  Exposed for dispatch
    tests and the kernel benchmark."""
    return resolve(kernels, op, tpu=on_tpu())


# ================================================================ attention
#: candidate flash-attention block sizes, largest first; both lq and lk
#: must be divisible by a candidate or PALLAS falls back to XLA.
_BLOCKS = (128, 64, 32, 16, 8)


def _pick_block(n: int) -> Optional[int]:
    for b in _BLOCKS:
        if n % b == 0:
            return b
    return None


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _attention_pallas(q, k, v, causal: bool, window: Optional[int],
                      block_q: int, block_k: int):
    return _fa.flash_attention_fwd(q, k, v, causal=causal, window=window,
                                   block_q=block_q, block_k=block_k,
                                   interpret=not on_tpu())


def _attention_pallas_fwd(q, k, v, causal, window, block_q, block_k):
    return _attention_pallas(q, k, v, causal, window, block_q, block_k), \
        (q, k, v)


def _attention_pallas_bwd(causal, window, block_q, block_k, res, dout):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: _ref.flash_attention_ref(
            q_, k_, v_, causal=causal, window=window), q, k, v)
    return vjp(dout)


_attention_pallas.defvjp(_attention_pallas_fwd, _attention_pallas_bwd)


def _attention_xla(q, k, v, *, causal: bool, window: Optional[int]):
    """Quadratic masked attention, the formulation ``models/layers.py``
    always ran on the unsharded path (f32 scores/softmax, probs cast to
    v's dtype for the PV matmul) — kept bit-identical so ``auto`` off
    TPU preserves historical numerics."""
    b, lq, hq, d = q.shape
    lk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    scores = jnp.einsum("blhd,bmhd->bhlm", q, k,
                        preferred_element_type=jnp.float32) / math.sqrt(d)
    qpos = jnp.arange(lq)[:, None] + (lk - lq)
    kpos = jnp.arange(lk)[None, :]
    mask = jnp.ones((lq, lk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhlm,bmhd->blhd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: Optional[int] = None,
              kernels: str = AUTO) -> jax.Array:
    """q (b, lq, hq, d); k/v (b, lk, hkv, d); GQA broadcast; positions
    END-aligned (query i at absolute position lk - lq + i).

    PALLAS: the flash kernel (online softmax, (block_q, lk) working
    set); falls back to XLA when no ``_BLOCKS`` entry divides lq and
    lk.  XLA: the quadratic masked formulation.
    """
    kt = resolved("attention", kernels)
    if kt is KernelType.PALLAS:
        bq = _pick_block(q.shape[1])
        bk = _pick_block(k.shape[1])
        if bq is not None and bk is not None:
            return _attention_pallas(q, k, v, causal, window, bq, bk)
    return _attention_xla(q, k, v, causal=causal, window=window)


# ================================================================= rmsnorm
@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _rmsnorm_pallas(x, weight, eps: float):
    return _rn.rmsnorm(x, weight, eps=eps, interpret=not on_tpu())


def _rmsnorm_pallas_fwd(x, weight, eps):
    return _rmsnorm_pallas(x, weight, eps), (x, weight)


def _rmsnorm_pallas_bwd(eps, res, dout):
    x, weight = res
    _, vjp = jax.vjp(
        lambda x_, w_: _ref.rmsnorm_ref(x_, w_, eps), x, weight)
    return vjp(dout)


_rmsnorm_pallas.defvjp(_rmsnorm_pallas_fwd, _rmsnorm_pallas_bwd)


def rmsnorm(x: jax.Array, weight: jax.Array, *, eps: float = 1e-6,
            kernels: str = AUTO) -> jax.Array:
    """x (..., d), weight (d,) -> same shape/dtype as x; f32 reduction."""
    kt = resolved("rmsnorm", kernels)
    if kt is KernelType.PALLAS:
        return _rmsnorm_pallas(x, weight, eps)
    return _ref.rmsnorm_ref(x, weight, eps)


# ======================================================== residual+rmsnorm
@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _residual_rmsnorm_pallas(x, res, weight, eps: float):
    return _rrn.residual_rmsnorm(x, res, weight, eps=eps,
                                 interpret=not on_tpu())


def _residual_rmsnorm_pallas_fwd(x, res, weight, eps):
    return _residual_rmsnorm_pallas(x, res, weight, eps), (x, res, weight)


def _residual_rmsnorm_pallas_bwd(eps, saved, dout):
    x, res, weight = saved
    _, vjp = jax.vjp(
        lambda x_, r_, w_: _ref.residual_rmsnorm_ref(x_, r_, w_, eps),
        x, res, weight)
    return vjp(dout)


_residual_rmsnorm_pallas.defvjp(_residual_rmsnorm_pallas_fwd,
                                _residual_rmsnorm_pallas_bwd)


def residual_rmsnorm(x: jax.Array, res: jax.Array, weight: jax.Array, *,
                     eps: float = 1e-6, kernels: str = AUTO
                     ) -> Tuple[jax.Array, jax.Array]:
    """Fused pre-norm block glue: ``s = x + res`` (f32) ->
    ``(s, rms_norm(s) * weight)``, both in x's dtype.  ``s`` is the
    residual stream the next sublayer adds onto; the normed output
    feeds the current one."""
    kt = resolved("residual_rmsnorm", kernels)
    if kt is KernelType.PALLAS:
        return _residual_rmsnorm_pallas(x, res, weight, eps)
    return _ref.residual_rmsnorm_ref(x, res, weight, eps)


# ================================================================ ssm scan
@functools.partial(jax.custom_vjp, nondiff_argnums=(6,))
def _ssm_scan_pallas(u, delta, a, bmat, cmat, h0, chunk: int):
    return _scan.ssm_scan(u, delta, a, bmat, cmat, h0, chunk=chunk,
                          interpret=not on_tpu())


def _ssm_scan_pallas_fwd(u, delta, a, bmat, cmat, h0, chunk):
    return (_ssm_scan_pallas(u, delta, a, bmat, cmat, h0, chunk),
            (u, delta, a, bmat, cmat, h0))


def _ssm_scan_pallas_bwd(chunk, saved, dout):
    u, delta, a, bmat, cmat, h0 = saved
    _, vjp = jax.vjp(_ref.ssm_scan_ref, u, delta, a, bmat, cmat, h0)
    return vjp(dout)


_ssm_scan_pallas.defvjp(_ssm_scan_pallas_fwd, _ssm_scan_pallas_bwd)


def _ssm_scan_associative(u, delta, a, bmat, cmat, h0, chunk: int):
    """Chunked associative scan (the formulation ``models/ssm.py``
    historically inlined as ``_ssm_chunked``): within a chunk the
    recurrence composes via ``jax.lax.associative_scan`` on
    (A-product, B-accumulate) pairs; a ``lax.scan`` carries the state
    across chunk boundaries, bounding the materialized state to
    (chunk, di, ds) instead of (l, di, ds).  All math in f32."""
    b, l, di = u.shape
    ds = a.shape[-1]
    uf = u.astype(jnp.float32)
    df = delta.astype(jnp.float32)
    af = a.astype(jnp.float32)
    da = df[..., None] * af[None, None]                        # (b,l,di,ds)
    abar = jnp.exp(da)
    bbar = df[..., None] * bmat.astype(jnp.float32)[:, :, None, :] \
        * uf[..., None]

    nc = max(1, l // chunk)
    abar = abar.reshape(b, nc, chunk, di, ds)
    bbar = bbar.reshape(b, nc, chunk, di, ds)
    cseq = cmat.astype(jnp.float32).reshape(b, nc, chunk, ds)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    def chunk_step(h, xs):
        ac, bc, cc = xs              # (b, chunk, di, ds) x2, (b, chunk, ds)
        acc_a, acc_b = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        hs = acc_a * h[:, None] + acc_b
        y = jnp.einsum("bcds,bcs->bcd", hs, cc)
        return hs[:, -1], y

    h_last, ys = jax.lax.scan(
        chunk_step, h0.astype(jnp.float32),
        (abar.transpose(1, 0, 2, 3, 4), bbar.transpose(1, 0, 2, 3, 4),
         cseq.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3).reshape(b, l, di)
    return y.astype(u.dtype), h_last


def ssm_scan(u: jax.Array, delta: jax.Array, a: jax.Array,
             bmat: jax.Array, cmat: jax.Array, h0: jax.Array, *,
             chunk: int = 128, kernels: str = AUTO
             ) -> Tuple[jax.Array, jax.Array]:
    """Selective scan (Mamba S6): ``h_t = exp(delta_t A) h_{t-1} +
    delta_t B_t u_t; y_t = C_t . h_t``.

    u/delta (b, l, di); a (di, ds); bmat/cmat (b, l, ds); h0 (b, di, ds)
    -> (y (b, l, di) in u's dtype, h_last (b, di, ds) f32).  ``chunk``
    is clamped to l and forced to l when it does not divide.
    """
    l = u.shape[1]
    chunk = min(chunk, l) if chunk > 0 else l
    if l % chunk:
        chunk = l
    kt = resolved("ssm_scan", kernels)
    if kt is KernelType.PALLAS:
        return _ssm_scan_pallas(u, delta, a, bmat, cmat, h0, chunk)
    if kt is KernelType.XLA_ASSOCIATIVE:
        return _ssm_scan_associative(u, delta, a, bmat, cmat, h0, chunk)
    return _ref.ssm_scan_ref(u, delta, a, bmat, cmat, h0)
