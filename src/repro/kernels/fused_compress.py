"""Fused wire-compression kernels over the packed (rows, 512) buffer.

The tree-path compressors (``repro.optim.compression``) run one XLA
dispatch chain *per pytree leaf*: quantize, dequantize and the
error-feedback update each read/write the leaf separately, and the tail
of small leaves pays one dispatch each.  On the packed wire format the
whole shard is a single lane-aligned buffer, so the entire
compress-decode-error-feedback pipeline fuses into ONE Pallas pass
through VMEM per shard:

    read  g (wire dtype), e (f32)          2 transfers
    write g' (decoded),  e' (f32)          2 transfers

vs. the unfused chain's 6+ (read g,e; write q; read q; write g'; write
e') — and one kernel launch per *shard* instead of one dispatch chain
per *leaf*.

Scale granularity: one scale per (8, 512) grid tile instead of the tree
path's one per tensor.  Per-tile scaling is *finer* (4096 elements share
a scale — strictly better quantization error than per-tensor on large
leaves) and is what keeps the kernel single-pass: a per-shard scale
would need a global max reduction before quantizing (two passes).  The
trade is visible only in the tests' tolerance, not in the API.

Both kernels emit the DECODED gradient (like the tree compressors): the
convergence-relevant information loss is what the experiments study;
the wire-byte reduction is priced by ``wire_bytes_per_value`` in the
roofline accounting.

``repro.kernels.ref`` holds the pure-jnp oracles
(``fused_int8_ef_ref`` / ``fused_topk_ef_ref``).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.obs.trace import TRACE
from repro.perfcount import WIRE
from repro.wireformat import WIRE_LANES as _LANES
from repro.wireformat import WIRE_ROWS as _ROWS

#: Bisection steps for the top-k threshold search.  24 halvings of the
#: [0, max|g|] interval pin the threshold to ~6e-8 of the dynamic range
#: — indistinguishable from the exact k-th order statistic for f32.
_TOPK_BISECT_ITERS = 24


def _check_wire(buf: jax.Array, err: jax.Array) -> None:
    if buf.ndim != 2 or buf.shape[1] != _LANES or buf.shape[0] % _ROWS:
        raise ValueError(
            f"expected an 8-row-aligned (rows, {_LANES}) wire buffer, "
            f"got {buf.shape}")
    if err.shape != buf.shape:
        raise ValueError(f"error state {err.shape} != buffer {buf.shape}")


# ------------------------------------------------------------------ int8
def _int8_ef_kernel(g_ref, e_ref, dq_ref, er_ref):
    gf = g_ref[...].astype(jnp.float32) + e_ref[...]
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127.0, 127.0)
    dq = q * scale
    dq_ref[...] = dq.astype(dq_ref.dtype)
    er_ref[...] = gf - dq


def fused_int8_ef(g: jax.Array, err: jax.Array, *,
                  interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """int8 quantize + dequant + error feedback, one pass over the wire.

    ``g`` is a packed (rows, 512) gradient buffer (rows % 8 == 0),
    ``err`` the carried f32 error state of the same shape.  Returns
    (decoded gradient in ``g.dtype``, new error state).
    """
    _check_wire(g, err)
    rows = g.shape[0]
    if rows == 0:
        return g, err
    WIRE.pallas_calls += 1
    if TRACE.enabled:
        TRACE.instant("kernel_launch", args={"kernel": "fused_int8_ef"})
    spec = pl.BlockSpec((_ROWS, _LANES), lambda i: (i, 0))
    return pl.pallas_call(
        _int8_ef_kernel,
        grid=(rows // _ROWS,),
        in_specs=[spec, spec],
        out_specs=(spec, spec),
        out_shape=(jax.ShapeDtypeStruct((rows, _LANES), g.dtype),
                   jax.ShapeDtypeStruct((rows, _LANES), jnp.float32)),
        interpret=interpret,
    )(g, err)


# ------------------------------------------------------------------ top-k
def _topk_ef_kernel(g_ref, e_ref, dq_ref, er_ref, *, fraction: float):
    gf = g_ref[...].astype(jnp.float32) + e_ref[...]
    mag = jnp.abs(gf)
    target = jnp.float32(fraction * mag.size)
    # Threshold = ~k-th largest magnitude, found by bisecting the count
    # curve c(t) = |{x : |x| >= t}| (monotone in t).  A sort/top_k inside
    # the kernel would break the single-VMEM-pass property; the bisection
    # is pure elementwise-compare + reduce, unrolled at trace time.
    lo = jnp.float32(0.0)
    hi = jnp.max(mag) + jnp.float32(1e-12)
    for _ in range(_TOPK_BISECT_ITERS):
        mid = 0.5 * (lo + hi)
        keep_mid = jnp.sum((mag >= mid).astype(jnp.float32))
        take = keep_mid >= target
        lo = jnp.where(take, mid, lo)
        hi = jnp.where(take, hi, mid)
    kept = jnp.where(mag >= lo, gf, 0.0)
    dq_ref[...] = kept.astype(dq_ref.dtype)
    er_ref[...] = gf - kept


def fused_topk_ef(g: jax.Array, err: jax.Array, *, fraction: float = 0.05,
                  interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Magnitude top-k sparsification + error feedback on the wire buffer.

    Keeps ~``fraction`` of each (8, 512) tile (>= fraction, ties kept),
    zeroes the rest, carries the sparsification residual in ``err``.
    """
    if not 0.0 < fraction <= 1.0:
        raise ValueError("fraction in (0, 1]")
    _check_wire(g, err)
    rows = g.shape[0]
    if rows == 0:
        return g, err
    WIRE.pallas_calls += 1
    if TRACE.enabled:
        TRACE.instant("kernel_launch", args={"kernel": "fused_topk_ef"})
    spec = pl.BlockSpec((_ROWS, _LANES), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_topk_ef_kernel, fraction=fraction),
        grid=(rows // _ROWS,),
        in_specs=[spec, spec],
        out_specs=(spec, spec),
        out_shape=(jax.ShapeDtypeStruct((rows, _LANES), g.dtype),
                   jax.ShapeDtypeStruct((rows, _LANES), jnp.float32)),
        interpret=interpret,
    )(g, err)
