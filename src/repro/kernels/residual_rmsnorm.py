"""Fused residual-add + RMSNorm (Pallas TPU).

The pre-norm block pattern ``y = x + sublayer(...); h = rms_norm(y)``
makes XLA read the freshly-written sum back from HBM to normalize it.
This kernel fuses the two: one pass streams ``x`` and ``res`` through
VMEM, writes the sum (the next layer's residual stream) AND its
normalized projection — two reads + two writes instead of three reads +
two writes, and the f32 mean-square reduction never leaves VMEM.

SNIPPETS.md's mamba-jax interface lists exactly this op as its open
TODO (``def add_norm(): pass``); this is the filled-in version.

Grid: row blocks over the flattened (rows, d) view, same tiling as
``kernels/rmsnorm.py`` — a (8, d) f32 tile stays comfortably in VMEM
for every model width in the zoo.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _residual_rmsnorm_kernel(x_ref, r_ref, w_ref, s_ref, o_ref, *,
                             eps: float):
    s = x_ref[...].astype(jnp.float32) + r_ref[...].astype(jnp.float32)
    var = jnp.mean(s * s, axis=-1, keepdims=True)
    s_ref[...] = s.astype(s_ref.dtype)
    o_ref[...] = (s * jax.lax.rsqrt(var + eps)
                  * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def residual_rmsnorm(x: jax.Array, res: jax.Array, weight: jax.Array, *,
                     eps: float = 1e-6, block_rows: int = 8,
                     interpret: bool = False
                     ) -> Tuple[jax.Array, jax.Array]:
    """x/res (..., d), weight (d,) -> (sum, rms_norm(sum) * weight).

    ``sum`` (= x + res) is the residual stream the next sublayer adds
    onto; the normalized output feeds the current sublayer.  Both carry
    x's dtype.
    """
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    r2 = res.reshape(rows, d)
    block_rows = min(block_rows, rows)
    if rows % block_rows:
        block_rows = 1
    grid = (rows // block_rows,)

    summed, normed = pl.pallas_call(
        functools.partial(_residual_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, d), x.dtype),
            jax.ShapeDtypeStruct((rows, d), x.dtype),
        ],
        interpret=interpret,
    )(x2, r2, weight)
    return summed.reshape(orig_shape), normed.reshape(orig_shape)
