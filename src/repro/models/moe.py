"""Mixture-of-Experts FFN (GShard-style capacity dispatch, expert-parallel).

Top-k routing with per-(row, chunk) capacity: the sequence is processed in
chunks via ``lax.scan`` so the dispatch/combine one-hot tensors stay small
(VMEM/HBM friendly), while expert weights are sharded over the 'model'
mesh axis (EP).  GSPMD inserts the token all-to-all at the
batch-sharded -> expert-sharded einsum boundary.

Supports DeepSeek-MoE style *shared experts* (always-on) next to the
routed ones, and emits the standard load-balancing auxiliary loss.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.params import ParamDef
from repro.models.sharding import shard


def moe_defs(cfg: ModelConfig, n: int) -> Dict[str, ParamDef]:
    m = cfg.moe
    d = cfg.d_model
    f = m.d_expert or cfg.d_ff
    e = m.n_experts
    defs: Dict[str, ParamDef] = {
        "router": ParamDef((n, d, e), (None, "fsdp", None), fan_in_dims=(1,)),
        "w_gate": ParamDef((n, e, d, f), (None, "model", "fsdp", None),
                           fan_in_dims=(2,)),
        "w_up": ParamDef((n, e, d, f), (None, "model", "fsdp", None),
                         fan_in_dims=(2,)),
        "w_down": ParamDef((n, e, f, d), (None, "model", None, "fsdp"),
                           fan_in_dims=(2,)),
    }
    if m.n_shared:
        fs = f * m.n_shared
        defs["shared_gate"] = ParamDef((n, d, fs), (None, "fsdp", "model"),
                                       fan_in_dims=(1,))
        defs["shared_up"] = ParamDef((n, d, fs), (None, "fsdp", "model"),
                                     fan_in_dims=(1,))
        defs["shared_down"] = ParamDef((n, fs, d), (None, "model", "fsdp"),
                                       fan_in_dims=(1,))
    return defs


def _route(cfg: ModelConfig, x: jax.Array, router: jax.Array,
           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x (b, s, d) -> combine (b, s, e, c) f32, dispatch (same, model dtype),
    aux load-balance loss (scalar)."""
    m = cfg.moe
    e, k = m.n_experts, m.top_k
    s = x.shape[1]
    capacity = max(k, int(m.capacity_factor * s * k / e))

    logits = jnp.einsum("bsd,de->bse", x, router,
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # (b, s, e)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)             # (b, s, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)       # renormalize

    # Load-balance aux loss (Switch/GShard): e * Σ_e fraction_e · meanprob_e
    assign1 = jax.nn.one_hot(expert_idx[..., 0], e, dtype=jnp.float32)
    frac = jnp.mean(assign1, axis=(0, 1))
    meanp = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac * meanp)

    # Position-in-expert per (row, chunk) group, k slots in priority order.
    combine = jnp.zeros((x.shape[0], s, e, capacity), jnp.float32)
    base = jnp.zeros((x.shape[0], 1, e), jnp.float32)           # used slots
    for j in range(k):
        onehot_e = jax.nn.one_hot(expert_idx[..., j], e,
                                  dtype=jnp.float32)            # (b, s, e)
        pos = jnp.cumsum(onehot_e, axis=1) - onehot_e + base    # (b, s, e)
        within = (pos < capacity) & (onehot_e > 0)
        pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), capacity,
                                dtype=jnp.float32)              # (b,s,e,c)
        combine = combine + (gate_vals[..., j][..., None, None]
                             * within[..., None] * pos_oh * onehot_e[..., None])
        base = base + jnp.sum(onehot_e, axis=1, keepdims=True)
    dispatch = (combine > 0).astype(x.dtype)
    return combine, dispatch, aux


def _expert_ffn(cfg: ModelConfig, xe: jax.Array, w: Dict[str, Any]) -> jax.Array:
    """xe (e, b, c, d) expert-sharded -> (e, b, c, d)."""
    gate = jnp.einsum("ebcd,edf->ebcf", xe, w["w_gate"])
    up = jnp.einsum("ebcd,edf->ebcf", xe, w["w_up"])
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(xe.dtype) * up
    h = shard(h, "model", "batch", None, None)
    return jnp.einsum("ebcf,efd->ebcd", h, w["w_down"])


def _moe_chunk(cfg: ModelConfig, x: jax.Array, w: Dict[str, Any],
               ) -> Tuple[jax.Array, jax.Array]:
    """Route+dispatch+compute+combine for one (b, chunk, d) slab."""
    combine, dispatch, aux = _route(cfg, x, w["router"])
    # batch-sharded -> expert-sharded (GSPMD all-to-all happens here)
    xe = jnp.einsum("bsd,bsec->ebcd", x, dispatch)
    xe = shard(xe, "model", "batch", None, None)
    ye = _expert_ffn(cfg, xe, w)
    y = jnp.einsum("ebcd,bsec->bsd", ye, combine.astype(x.dtype))
    return shard(y, "batch", None, None), aux


def moe_block(cfg: ModelConfig, x: jax.Array, w: Dict[str, Any],
              ) -> Tuple[jax.Array, jax.Array]:
    """x (b, l, d) -> (y (b, l, d), aux scalar). Scans over seq chunks."""
    m = cfg.moe
    b, l, d = x.shape
    chunk = min(cfg.moe_chunk, l) if cfg.moe_chunk > 0 else l
    out_shared = jnp.zeros_like(x)
    if m.n_shared:
        gate = jnp.einsum("bld,df->blf", x, w["shared_gate"])
        up = jnp.einsum("bld,df->blf", x, w["shared_up"])
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
        h = shard(h, "batch", None, "model")
        out_shared = jnp.einsum("blf,fd->bld", h, w["shared_down"])

    if chunk >= l or l % chunk != 0:   # decode / cost-mode: single dispatch
        y, aux = _moe_chunk(cfg, x, w)
        return y + out_shared, aux

    n_chunks = l // chunk
    xs = x.reshape(b, n_chunks, chunk, d).transpose(1, 0, 2, 3)

    def step(_, xc):
        y, aux = _moe_chunk(cfg, xc, w)
        return (), (y, aux)

    _, (ys, auxs) = jax.lax.scan(step, (), xs)
    y = ys.transpose(1, 0, 2, 3).reshape(b, l, d)
    return y + out_shared, jnp.mean(auxs)
