"""Whisper-style encoder-decoder backbone.

The mel/conv frontend is a STUB per the assignment brief: ``input_specs``
provides precomputed frame embeddings (batch, frames, d_model) — the
encoder consumes them after adding sinusoidal positions.  The decoder is a
standard pre-LN transformer with causal self-attention + cross-attention,
GELU MLP, LayerNorm (with bias) and tied embeddings, matching Whisper.

Serving: ``encode`` runs once per request; decode keeps a self-attention
KV ring cache plus the *precomputed* cross-attention K/V of the encoder
output (computed at prefill, static afterwards).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.params import ParamDef
from repro.models.sharding import shard


def param_defs(cfg: ModelConfig) -> Dict[str, Any]:
    ne = cfg.n_encoder_layers or cfg.n_layers
    nd = cfg.n_layers
    d = cfg.d_model
    enc_layer = {
        "attn": T.attn_defs(cfg, ne),
        "attn_norm": T.norm_defs(cfg, ne),
        "mlp": T.mlp_defs(cfg, ne),
        "mlp_norm": T.norm_defs(cfg, ne),
    }
    dec_layer = {
        "self_attn": T.attn_defs(cfg, nd),
        "self_norm": T.norm_defs(cfg, nd),
        "cross_attn": T.attn_defs(cfg, nd),
        "cross_norm": T.norm_defs(cfg, nd),
        "mlp": T.mlp_defs(cfg, nd),
        "mlp_norm": T.norm_defs(cfg, nd),
    }
    return {
        "embed": ParamDef((cfg.padded_vocab, d), ("model", "fsdp"),
                          init="embed", fan_in_dims=(1,)),
        # sized for the largest assigned decode shape (32k); real whisper
        # caps at 448 — the backbone is exercised at the assigned shapes
        "pos_embed": ParamDef((32768, d), (None, "fsdp"), scale=0.02),
        "encoder": enc_layer,
        "enc_final": T._unstack_norm(cfg),
        "decoder": dec_layer,
        "dec_final": T._unstack_norm(cfg),
    }


def _sinusoid(length: int, d: int) -> jax.Array:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _xattn(cfg: ModelConfig, x: jax.Array, w: Dict[str, Any],
           kv_src: jax.Array = None, mask=None,
           precomputed_kv=None) -> jax.Array:
    """Self- or cross-attention without rotary (whisper uses abs pos)."""
    q = jnp.einsum("bld,dhk->blhk", x, w["wq"])
    if cfg.qkv_bias:
        q = q + w["bq"]
    if precomputed_kv is not None:
        k, v = precomputed_kv
    else:
        src = x if kv_src is None else kv_src
        k = jnp.einsum("bld,dhk->blhk", src, w["wk"])
        v = jnp.einsum("bld,dhk->blhk", src, w["wv"])
        if cfg.qkv_bias:
            k, v = k + w["bk"], v + w["bv"]
    if mask is None:
        mask = jnp.ones((q.shape[1], k.shape[1]), bool)
    out = L.attention(cfg, q, k, v, mask=mask)
    return jnp.einsum("blhk,hkd->bld", out, w["wo"])


def encode(cfg: ModelConfig, params: Dict[str, Any],
           frames: jax.Array) -> jax.Array:
    """frames (b, l_enc, d_model) -> encoder states (b, l_enc, d_model)."""
    b, l, d = frames.shape
    x = (frames.astype(jnp.dtype(cfg.dtype))
         + _sinusoid(l, d).astype(jnp.dtype(cfg.dtype))[None])
    x = shard(x, "batch", None, None)

    def body(carry, w):
        h = L.apply_norm(cfg, carry, w["attn_norm"])
        y = carry + _xattn(cfg, h, w["attn"])
        h = L.apply_norm(cfg, y, w["mlp_norm"])
        return y + L.mlp_block(cfg, h, w["mlp"]), ()

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["encoder"],
                        unroll=cfg.scan_unroll)
    return L.apply_norm(cfg, x, params["enc_final"])


def decode_train(cfg: ModelConfig, params: Dict[str, Any], tokens: jax.Array,
                 enc: jax.Array) -> jax.Array:
    b, l = tokens.shape
    x = (L.embed(tokens, params["embed"])
         + params["pos_embed"][:l][None]).astype(jnp.dtype(cfg.dtype))
    mask = L.causal_window_mask(l, l)

    def body(carry, w):
        h = L.apply_norm(cfg, carry, w["self_norm"])
        y = carry + _xattn(cfg, h, w["self_attn"], mask=mask)
        h = L.apply_norm(cfg, y, w["cross_norm"])
        y = y + _xattn(cfg, h, w["cross_attn"], kv_src=enc)
        h = L.apply_norm(cfg, y, w["mlp_norm"])
        return y + L.mlp_block(cfg, h, w["mlp"]), ()

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["decoder"],
                        unroll=cfg.scan_unroll)
    x = L.apply_norm(cfg, x, params["dec_final"])
    return L.unembed(x, params["embed"], cfg.vocab_size)


def forward(cfg: ModelConfig, params: Dict[str, Any],
            batch: Dict[str, jax.Array]) -> Tuple[jax.Array, jax.Array]:
    enc = encode(cfg, params, batch["frames"])
    logits = decode_train(cfg, params, batch["tokens"], enc)
    return logits, jnp.zeros((), jnp.float32)


# --------------------------------------------------------------- serving
def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               enc_len: int) -> Dict[str, Any]:
    nd = cfg.n_layers
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    return {
        "self_k": jnp.zeros((nd, batch, max_seq, hkv, hd), dt),
        "self_v": jnp.zeros((nd, batch, max_seq, hkv, hd), dt),
        "cross_k": jnp.zeros((nd, batch, enc_len, hkv, hd), dt),
        "cross_v": jnp.zeros((nd, batch, enc_len, hkv, hd), dt),
    }


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int, enc_len: int,
                rules) -> Dict[str, Any]:
    from jax.sharding import PartitionSpec as P
    nd = cfg.n_layers
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    axes = (None, "batch", "cache_seq", None, None)

    def spec(s):
        return P() if rules is None else rules.spec(axes, s)

    return {
        "self_k": spec((nd, batch, max_seq, hkv, hd)),
        "self_v": spec((nd, batch, max_seq, hkv, hd)),
        "cross_k": spec((nd, batch, enc_len, hkv, hd)),
        "cross_v": spec((nd, batch, enc_len, hkv, hd)),
    }


def prefill_cross_kv(cfg: ModelConfig, params: Dict[str, Any],
                     enc: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Cross K/V for all decoder layers from the encoder output."""
    def per_layer(w):
        k = jnp.einsum("bld,dhk->blhk", enc, w["cross_attn"]["wk"])
        v = jnp.einsum("bld,dhk->blhk", enc, w["cross_attn"]["wv"])
        if cfg.qkv_bias:
            k = k + w["cross_attn"]["bk"]
            v = v + w["cross_attn"]["bv"]
        return k.astype(jnp.dtype(cfg.dtype)), v.astype(jnp.dtype(cfg.dtype))

    ks, vs = jax.lax.map(lambda w: per_layer(w), params["decoder"])
    return ks, vs


def forward_decode(cfg: ModelConfig, params: Dict[str, Any],
                   token: jax.Array, cache: Dict[str, Any],
                   index: jax.Array) -> Tuple[jax.Array, Dict[str, Any]]:
    x = (L.embed(token, params["embed"])
         + params["pos_embed"][index][None, None]).astype(jnp.dtype(cfg.dtype))

    def body(carry, xs):
        w, sk, sv, ck, cv = xs
        h = L.apply_norm(cfg, carry, w["self_norm"])
        att, ncache = L.decode_attention_block(
            cfg, h, w["self_attn"], {"k": sk, "v": sv}, index)
        y = carry + att
        h = L.apply_norm(cfg, y, w["cross_norm"])
        y = y + _xattn(cfg, h, w["cross_attn"], precomputed_kv=(ck, cv))
        h = L.apply_norm(cfg, y, w["mlp_norm"])
        y = y + L.mlp_block(cfg, h, w["mlp"])
        return y, (ncache["k"], ncache["v"])

    xs = (params["decoder"], cache["self_k"], cache["self_v"],
          cache["cross_k"], cache["cross_v"])
    x, (nk, nv) = jax.lax.scan(body, x, xs, unroll=cfg.scan_unroll)
    x = L.apply_norm(cfg, x, params["dec_final"])
    logits = L.unembed(x, params["embed"], cfg.vocab_size)
    new_cache = dict(cache)
    new_cache["self_k"], new_cache["self_v"] = nk, nv
    return logits, new_cache
