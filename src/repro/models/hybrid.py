"""Jamba-style hybrid: Mamba/attention 1:7 interleave + MoE every 2nd layer.

Layer ``i`` is an attention layer iff ``i % attn_period == attn_offset``
(Jamba: period 8); the FFN sublayer is MoE on every ``moe.every``-th layer
(Jamba: 2), dense SwiGLU otherwise.  Layers are scanned in *period groups*:
the 8 slots of one period are unrolled in the scan body (their param
structure differs), the scan runs over ``n_layers / period`` groups — HLO
stays small at 32+ layers.

Decode state = {mamba conv tails + ssm states} ∪ {KV caches for the
attention layers}.  With 7/8 layers recurrent, long-context decode is
sub-quadratic: only the few attention layers keep a full-length cache
(sequence-sharded over the mesh).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.params import ParamDef


def _slot_kinds(cfg: ModelConfig):
    """Per period-slot: ('attn'|'mamba', 'moe'|'mlp')."""
    period = cfg.attn_period or 1
    kinds = []
    for j in range(period):
        mixer = "attn" if j == cfg.attn_offset else "mamba"
        ffn = "moe" if (cfg.moe is not None
                        and j % cfg.moe.every == cfg.moe.every - 1) else "mlp"
        kinds.append((mixer, ffn))
    return kinds


def param_defs(cfg: ModelConfig) -> Dict[str, Any]:
    period = cfg.attn_period or 1
    if cfg.n_layers % period:
        raise ValueError("n_layers must be a multiple of attn_period")
    groups = cfg.n_layers // period
    slots = []
    for mixer, ffn in _slot_kinds(cfg):
        slot: Dict[str, Any] = {
            "mixer_norm": T.norm_defs(cfg, groups),
            "ffn_norm": T.norm_defs(cfg, groups),
        }
        if mixer == "attn":
            slot["attn"] = T.attn_defs(cfg, groups)
        else:
            slot["mamba"] = ssm.mamba_defs(cfg, groups)
        if ffn == "moe":
            slot["moe"] = moe_lib.moe_defs(cfg, groups)
        else:
            slot["mlp"] = T.mlp_defs(cfg, groups)
        slots.append(slot)
    defs: Dict[str, Any] = {
        "embed": ParamDef((cfg.padded_vocab, cfg.d_model), ("model", "fsdp"),
                          init="embed", fan_in_dims=(1,)),
        "final_norm": {"scale": ParamDef((cfg.d_model,), (None,),
                                         init="ones")},
        "slots": tuple(slots),
    }
    if not cfg.tie_embeddings:
        defs["unembed"] = ParamDef((cfg.padded_vocab, cfg.d_model),
                                   ("model", "fsdp"), fan_in_dims=(1,))
    return defs


def _slot_body(cfg: ModelConfig, mixer: str, ffn: str, x, w, mask):
    h = L.apply_norm(cfg, x, w["mixer_norm"])
    if mixer == "attn":
        cos = sin = jnp.zeros(())            # rope off for jamba
        mix = L.attention_block(cfg, h, w["attn"], cos, sin, mask)
    else:
        mix = ssm.mamba_block(cfg, h, w["mamba"])
    # fused residual-add + norm via the kernel registry
    x, h = L.residual_apply_norm(cfg, mix, x, w["ffn_norm"])
    if ffn == "moe":
        out, aux = moe_lib.moe_block(cfg, h, w["moe"])
    else:
        out, aux = L.mlp_block(cfg, h, w["mlp"]), jnp.zeros((), jnp.float32)
    return x + out, aux


def _group_body(cfg: ModelConfig, kinds, x, group_w, mask):
    aux_total = jnp.zeros((), jnp.float32)
    for (mixer, ffn), w in zip(kinds, group_w):
        fn = functools.partial(_slot_body, cfg, mixer, ffn, mask=mask)
        if cfg.remat == "full":
            # per-slot remat inside the (already checkpointed) period
            # group: the group backward otherwise keeps 7 mamba layers'
            # chunked-scan internals live at once
            fn = jax.checkpoint(fn)
        x, aux = fn(x, w)
        aux_total = aux_total + aux
    return x, aux_total


def forward(cfg: ModelConfig, params: Dict[str, Any], tokens: jax.Array,
            ) -> Tuple[jax.Array, jax.Array]:
    b, l = tokens.shape
    kinds = _slot_kinds(cfg)
    x = L.embed(tokens, params["embed"]).astype(jnp.dtype(cfg.dtype))
    mask = L.causal_window_mask(l, l, window=cfg.sliding_window)
    body = functools.partial(_group_body, cfg, kinds, mask=mask)
    if cfg.remat == "full":
        body = jax.checkpoint(body)

    def step(carry, group_w):
        y, aux = body(carry, group_w)
        return y, aux

    x, auxs = jax.lax.scan(step, x, params["slots"],
                           unroll=cfg.scan_unroll)
    x = L.rms_norm(x, params["final_norm"]["scale"], kernels=cfg.kernels)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return L.unembed(x, table, cfg.vocab_size), jnp.sum(auxs)


# --------------------------------------------------------------- serving
def init_state(cfg: ModelConfig, batch: int, max_seq: int) -> Dict[str, Any]:
    period = cfg.attn_period or 1
    groups = cfg.n_layers // period
    di = cfg.expand * cfg.d_model
    state: Dict[str, Any] = {
        "kv": {  # one attention layer per group
            "k": jnp.zeros((groups, batch, max_seq, cfg.n_kv_heads,
                            cfg.resolved_head_dim), jnp.dtype(cfg.dtype)),
            "v": jnp.zeros((groups, batch, max_seq, cfg.n_kv_heads,
                            cfg.resolved_head_dim), jnp.dtype(cfg.dtype)),
        },
        "mamba": {  # period-1 mamba layers per group
            "conv": jnp.zeros((groups, period - 1, batch, cfg.d_conv - 1, di),
                              jnp.dtype(cfg.dtype)),
            "h": jnp.zeros((groups, period - 1, batch, di, cfg.d_state),
                           jnp.float32),
        },
    }
    return state


def state_specs(cfg: ModelConfig, batch: int, max_seq: int, rules):
    from jax.sharding import PartitionSpec as P
    period = cfg.attn_period or 1
    groups = cfg.n_layers // period
    di = cfg.expand * cfg.d_model
    hd = cfg.resolved_head_dim

    def spec(axes, shape):
        return P() if rules is None else rules.spec(axes, shape)

    kv_shape = (groups, batch, max_seq, cfg.n_kv_heads, hd)
    kv = spec((None, "batch", "cache_seq", None, None), kv_shape)
    return {
        "kv": {"k": kv, "v": kv},
        "mamba": {
            "conv": spec((None, None, "batch", None, "model"),
                         (groups, period - 1, batch, cfg.d_conv - 1, di)),
            "h": spec((None, None, "batch", "model", None),
                      (groups, period - 1, batch, di, cfg.d_state)),
        },
    }


def forward_decode(cfg: ModelConfig, params: Dict[str, Any],
                   token: jax.Array, state: Dict[str, Any], index: jax.Array,
                   ) -> Tuple[jax.Array, Dict[str, Any]]:
    kinds = _slot_kinds(cfg)
    x = L.embed(token, params["embed"]).astype(jnp.dtype(cfg.dtype))

    def step(carry, xs):
        y = carry
        group_w, ck, cv, conv, hs = xs
        mi = 0  # mamba slot counter within the group
        nk, nv = ck, cv
        nconv, nh = conv, hs
        for (mixer, ffn), w in zip(kinds, group_w):
            h = L.apply_norm(cfg, y, w["mixer_norm"])
            if mixer == "attn":
                out, ncache = L.decode_attention_block(
                    cfg, h, w["attn"], {"k": ck, "v": cv}, index)
                nk, nv = ncache["k"], ncache["v"]
            else:
                st = {"conv": conv[mi], "h": hs[mi]}
                out, st2 = ssm.mamba_decode(
                    cfg, h, jax.tree_util.tree_map(lambda p: p, w["mamba"]),
                    st)
                nconv = nconv.at[mi].set(st2["conv"])
                nh = nh.at[mi].set(st2["h"])
                mi += 1
            y = y + out
            h = L.apply_norm(cfg, y, w["ffn_norm"])
            if ffn == "moe":
                out, _ = moe_lib.moe_block(cfg, h, w["moe"])
            else:
                out = L.mlp_block(cfg, h, w["mlp"])
            y = y + out
        return y, (nk, nv, nconv, nh)

    xs = (params["slots"], state["kv"]["k"], state["kv"]["v"],
          state["mamba"]["conv"], state["mamba"]["h"])
    x, (nk, nv, nconv, nh) = jax.lax.scan(step, x, xs,
                                          unroll=cfg.scan_unroll)
    x = L.rms_norm(x, params["final_norm"]["scale"], kernels=cfg.kernels)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    new_state = {"kv": {"k": nk, "v": nv},
                 "mamba": {"conv": nconv, "h": nh}}
    return L.unembed(x, table, cfg.vocab_size), new_state
