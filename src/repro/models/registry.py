"""Architecture registry: one place that maps a ModelConfig to its family's
param defs / forward / loss / serving functions and input specs.

Families:
  dense | moe   -> transformer.py   (llama/qwen/mistral/chameleon/qwen3/deepseek)
  ssm           -> ssm.py           (xLSTM)
  hybrid        -> hybrid.py        (jamba)
  audio         -> encdec.py        (whisper backbone, stub frontend)
  vlm           -> transformer.py   (chameleon: early-fusion VQ tokens = LM)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import encdec, hybrid, ssm, transformer
from repro.models import params as P
from repro.models.config import ModelConfig
from repro.models.layers import cross_entropy
from repro.models.sharding import AxisRules


@dataclasses.dataclass(frozen=True)
class Family:
    param_defs: Callable[[ModelConfig], Any]
    loss_fn: Callable[..., Tuple[jax.Array, Dict[str, Any]]]
    decode_fn: Optional[Callable[..., Any]] = None
    init_state: Optional[Callable[..., Any]] = None
    state_specs: Optional[Callable[..., Any]] = None


# --------------------------------------------------------------- loss fns
def _lm_loss(cfg, params, batch):
    return transformer.loss_fn(cfg, params, batch)


def _ssm_loss(cfg, params, batch):
    logits, aux = ssm.xlstm_forward(cfg, params, batch["tokens"])
    return cross_entropy(logits, batch["labels"]), {"aux_loss": aux}


def _hybrid_loss(cfg, params, batch):
    logits, aux = hybrid.forward(cfg, params, batch["tokens"])
    nll = cross_entropy(logits, batch["labels"])
    w = cfg.moe.aux_loss_weight if cfg.moe else 0.0
    return nll + w * aux, {"loss": nll, "aux_loss": aux}


def _encdec_loss(cfg, params, batch):
    logits, aux = encdec.forward(cfg, params, batch)
    return cross_entropy(logits, batch["labels"]), {"aux_loss": aux}


# --------------------------------------------------------------- decode fns
def _lm_decode(cfg, params, token, cache, index):
    return transformer.forward_decode(cfg, params, token, cache, index)


def _lm_init_state(cfg, batch, max_seq):
    return transformer.init_cache(cfg, batch, max_seq)


def _lm_state_specs(cfg, batch, max_seq, rules):
    return transformer.cache_specs(cfg, batch, max_seq, rules)


def _ssm_decode(cfg, params, token, state, index):
    return ssm.xlstm_decode(cfg, params, token, state, index)


def _ssm_init_state(cfg, batch, max_seq):
    return ssm.xlstm_init_state(cfg, batch)


def _ssm_state_specs(cfg, batch, max_seq, rules):
    from jax.sharding import PartitionSpec as PS
    state = jax.eval_shape(lambda: ssm.xlstm_init_state(cfg, batch))

    def leaf_spec(x):
        if rules is None:
            return PS()
        # (layer, batch, heads, [hd, [hd]]): batch over DP axes; the
        # per-head state dim over 'model' where it divides (192/16 ok)
        axes = [None] * x.ndim
        if x.ndim >= 2:
            axes[1] = "batch"
        if x.ndim >= 4:
            axes[3] = "model"
        return rules.spec(axes, x.shape)

    return jax.tree_util.tree_map(leaf_spec, state)


def _hybrid_state_specs(cfg, batch, max_seq, rules):
    return hybrid.state_specs(cfg, batch, max_seq, rules)


def _encdec_decode(cfg, params, token, cache, index):
    return encdec.forward_decode(cfg, params, token, cache, index)


FAMILIES: Dict[str, Family] = {
    "dense": Family(transformer.param_defs, _lm_loss, _lm_decode,
                    _lm_init_state, _lm_state_specs),
    "moe": Family(transformer.param_defs, _lm_loss, _lm_decode,
                  _lm_init_state, _lm_state_specs),
    "vlm": Family(transformer.param_defs, _lm_loss, _lm_decode,
                  _lm_init_state, _lm_state_specs),
    "ssm": Family(ssm.xlstm_param_defs, _ssm_loss, _ssm_decode,
                  _ssm_init_state, _ssm_state_specs),
    "hybrid": Family(hybrid.param_defs, _hybrid_loss, hybrid.forward_decode,
                     hybrid.init_state, _hybrid_state_specs),
    "audio": Family(encdec.param_defs, _encdec_loss, _encdec_decode,
                    encdec.init_cache, encdec.cache_specs),
}


def family(cfg: ModelConfig) -> Family:
    return FAMILIES[cfg.family]


# --------------------------------------------------------------- public API
def param_defs(cfg: ModelConfig) -> Any:
    return family(cfg).param_defs(cfg)


def init_params(cfg: ModelConfig, key: jax.Array) -> Any:
    return P.init_tree(param_defs(cfg), key, cfg.dtype)


def param_specs(cfg: ModelConfig, rules: Optional[AxisRules]) -> Any:
    return P.spec_tree(param_defs(cfg), rules)


def param_sds(cfg: ModelConfig) -> Any:
    return P.sds_tree(param_defs(cfg), cfg.dtype)


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    total = P.count(param_defs(cfg))
    if active_only and cfg.moe is not None:
        # subtract routed-expert params that are not active per token
        m = cfg.moe
        f = m.d_expert or cfg.d_ff
        per_expert = 3 * cfg.d_model * f
        n_moe_layers = sum(1 for i in range(cfg.n_layers)
                           if cfg.is_moe_layer(i))
        if cfg.family == "hybrid":
            period = cfg.attn_period or 1
            n_moe_layers = (cfg.n_layers // period) * sum(
                1 for j in range(period)
                if j % m.every == m.every - 1)
        inactive = n_moe_layers * (m.n_experts - m.top_k) * per_expert
        total -= max(0, inactive)
    return total


def loss_fn(cfg: ModelConfig) -> Callable:
    return functools.partial(family(cfg).loss_fn, cfg)


def decode_fn(cfg: ModelConfig) -> Callable:
    return functools.partial(family(cfg).decode_fn, cfg)
