"""Logical-axis sharding: one place that maps logical axes -> mesh axes.

Model code annotates params/activations with *logical* axes
('batch', 'model', 'fsdp', 'cache_seq', ...).  ``AxisRules`` maps them
onto physical mesh axes (('pod','data','model')) **size-aware**: a
mapping is dropped for a tensor dimension the mesh axes do not evenly
divide (e.g. qwen1.5-32b's 40 heads over a 16-way model axis, batch=1
long-context decode over the data axis, whisper's 51865 vocab).  With no
rules installed every annotation is a no-op, so identical model code runs
on 1 CPU device in smoke tests and on the 512-chip mesh in the dry-run.

This indirection is also what lets the perf loop re-shard without touching
model code: hillclimb iterations swap the rule set only.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


class AxisRules:
    """logical-axis -> mesh-axes mapping + mesh axis sizes for div checks."""

    def __init__(self, rules: Dict[str, MeshAxes],
                 axis_sizes: Optional[Dict[str, int]] = None,
                 mesh: Optional[jax.sharding.Mesh] = None):
        self.rules = dict(rules)
        self.axis_sizes = dict(axis_sizes or {})
        self.mesh = mesh
        self.attn_mode = "tp"   # 'tp' | 'sp', set by the rule builders

    def _mesh_axes(self, logical: Optional[str]) -> Tuple[str, ...]:
        m = self.rules.get(logical) if logical else None
        if m is None:
            return ()
        return (m,) if isinstance(m, str) else tuple(m)

    def _divides(self, dim: Optional[int], axes: Sequence[str]) -> bool:
        if dim is None or not self.axis_sizes:
            return True     # unknown shape: trust the caller
        n = 1
        for a in axes:
            n *= self.axis_sizes.get(a, 1)
        return n > 0 and dim % n == 0

    def spec(self, logical: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None) -> P:
        dims = list(shape) if shape is not None else [None] * len(logical)
        if shape is not None and len(dims) != len(logical):
            raise ValueError(f"rank mismatch: shape {shape} vs axes {logical}")
        phys, used = [], set()
        for ax, dim in zip(logical, dims):
            ms = tuple(a for a in self._mesh_axes(ax) if a not in used)
            # prefix fallback: a dim that does not divide the full tuple
            # may still divide a prefix (e.g. batch 32 over
            # ('data','model') = 256 -> shard over 'data' = 16 only)
            while ms and not self._divides(dim, ms):
                ms = ms[:-1]
            if ms:
                used.update(ms)
                phys.append(ms if len(ms) > 1 else ms[0])
            else:
                phys.append(None)
        while phys and phys[-1] is None:
            phys.pop()
        return P(*phys)


_state = threading.local()


def current_rules() -> Optional[AxisRules]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[AxisRules]):
    prev = current_rules()
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def logical_spec(logical: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None) -> P:
    r = current_rules()
    return P() if r is None else r.spec(logical, shape)


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint on logical axes; no-op without rules."""
    r = current_rules()
    if r is None or r.mesh is None:
        return x
    spec = r.spec(logical, x.shape)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(r.mesh, spec))


# -- standard rule sets -------------------------------------------------------
def _base_rules(batch_axes, *, fsdp: bool, sp: bool,
                role: str = "tp") -> Dict[str, MeshAxes]:
    """Shared logical->mesh mapping.

    sp=False (Megatron-TP attention): attention heads shard over 'model',
    activations replicated on 'model' between blocks.
    sp=True (sequence-parallel attention, the measured winner on the
    dry-run — EXPERIMENTS.md §Perf it.8-9): the activation seq dim is the
    canonical 'model' sharding; attention runs with heads UNsharded and
    queries seq-sharded (no l<->h layout transitions, no wo psum); the
    MLP keeps Megatron f-sharding with AG/RS at its boundary; attention
    weights replicate over 'model' but stay FSDP-sharded over 'data'.
    """
    if role == "dp":
        # small models: the 'model' axis joins data parallelism; params
        # and optimizer state ZeRO-shard over BOTH axes
        return {
            "batch": tuple(batch_axes) + ("model",),
            "model": None,
            "heads": None,
            "kv_heads": None,
            "fsdp": ("data", "model") if fsdp else None,
            "cache_seq": ("data", "model"),
            "seq": None,
            "attn_mode": None,
        }
    return {
        "batch": batch_axes,
        "model": "model",               # TP dim (ffn/vocab/experts)
        "heads": None if sp else "model",
        "kv_heads": None if sp else "model",
        "fsdp": "data" if fsdp else None,
        "cache_seq": ("data", "model"),  # decode KV-cache sequence shards
        "seq": "model" if sp else None,  # activation sequence dim
        "attn_mode": None,               # marker, read via .rules
    }


def single_pod_rules(axis_sizes: Optional[Dict[str, int]] = None, *,
                     fsdp: bool = True, sp: bool = True, role: str = "tp",
                     mesh: Optional[jax.sharding.Mesh] = None) -> AxisRules:
    """mesh ('data','model'): DP over data, TP/EP over model; params
    FSDP-sharded over data on a non-TP dim (ZeRO-3 style)."""
    r = AxisRules(_base_rules(("data",), fsdp=fsdp, sp=sp, role=role),
                  axis_sizes, mesh)
    r.attn_mode = "sp" if (sp and role == "tp") else "tp"
    return r


def multi_pod_rules(axis_sizes: Optional[Dict[str, int]] = None, *,
                    fsdp: bool = True, sp: bool = True, role: str = "tp",
                    mesh: Optional[jax.sharding.Mesh] = None) -> AxisRules:
    """mesh ('pod','data','model'): batch over pod×data; params replicated
    across pods (cross-pod traffic = grad all-reduce only, DCN-friendly —
    this is where DSSP's dynamic-period sync applies)."""
    r = AxisRules(_base_rules(("pod", "data"), fsdp=fsdp, sp=sp, role=role),
                  axis_sizes, mesh)
    r.attn_mode = "sp" if (sp and role == "tp") else "tp"
    return r


def rules_for_mesh(mesh: jax.sharding.Mesh, **kw) -> AxisRules:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    multi = "pod" in mesh.axis_names
    return (multi_pod_rules(sizes, mesh=mesh, **kw) if multi
            else single_pod_rules(sizes, mesh=mesh, **kw))
