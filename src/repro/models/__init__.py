"""Model zoo: dense/MoE transformers, xLSTM, Mamba hybrids, enc-dec."""

from repro.models.config import ModelConfig, MoEConfig
from repro.models.sharding import (
    AxisRules,
    rules_for_mesh,
    shard,
    use_rules,
)

__all__ = ["ModelConfig", "MoEConfig", "AxisRules", "rules_for_mesh",
           "shard", "use_rules"]
