"""Model configuration shared by every architecture family."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int            # routed experts
    top_k: int
    n_shared: int = 0         # always-on shared experts (DeepSeek-MoE)
    d_expert: int = 0         # expert FFN width (0 -> use d_ff)
    capacity_factor: float = 1.25
    every: int = 1            # MoE on every k-th layer (Jamba: 2)
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                     # 0 -> d_model // n_heads
    # attention
    qkv_bias: bool = False                # qwen1.5
    qk_norm: bool = False                 # chameleon
    sliding_window: Optional[int] = None  # h2o-danube SWA
    rope_theta: float = 10_000.0
    use_rope: bool = True                 # jamba/whisper: no rotary
    # MoE
    moe: Optional[MoEConfig] = None
    # hybrid (jamba): attention on layers where i % attn_period == attn_offset
    attn_period: int = 0
    attn_offset: int = 0
    # ssm
    ssm_kind: str = ""                    # "xlstm" | "mamba"
    slstm_layers: Tuple[int, ...] = ()    # xLSTM: which layers are sLSTM
    d_state: int = 16                     # mamba state dim
    d_conv: int = 4                       # mamba depthwise conv width
    expand: int = 2                       # mamba inner expansion
    # enc-dec (whisper)
    n_encoder_layers: int = 0
    # norm / glue
    norm: str = "rmsnorm"                 # rmsnorm | layernorm
    act: str = "silu"                     # silu (SwiGLU) | gelu
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: str = "full"                   # none | full  (scan-level remat)
    # chunking knobs: bound the materialized working set (HBM) without
    # changing the math; the cost-extraction mode of the dry-run disables
    # them so XLA's per-while cost under-count can be fixed by the
    # two-point depth fit (see roofline/analysis.py)
    attn_chunk: int = 512                 # 0 = full quadratic scores
    moe_chunk: int = 256                  # 0 = single dispatch
    mamba_chunk: int = 128                # 0 = single associative scan
    scan_unroll: bool = False             # unroll scan-over-layers (cost
                                          # extraction: while bodies are
                                          # cost-counted once by XLA)
    # optimizer selection for the training step (adafactor for the
    # largest models so optimizer state fits per-chip HBM; see DESIGN.md)
    optimizer: str = "adamw"
    # kernel variant selection for the worker-step hot ops, dispatched by
    # repro.kernels.registry ("auto" | variant | per-op overrides, see
    # repro.kernels.interface); validated upstream by api.spec
    kernels: str = "auto"
    # how the 'model' mesh axis is used: "tp" (tensor/expert parallel,
    # default) or "dp" (extra data parallelism + ZeRO param/opt sharding
    # -- the right choice for small models where 16-way TP is pure
    # overhead; measured 15x collective reduction on h2o-danube, §Perf)
    model_axis_role: str = "tp"
    # sequence-parallel attention (EXPERIMENTS.md §Perf it.9); ignored
    # when model_axis_role == "dp"
    sequence_parallel: bool = True
    # microbatch gradient accumulation: bounds the per-device activation
    # carry (remat saves one residual per layer per microbatch) so deep
    # models fit 16 GB/chip at global batch 256
    grad_accum: int = 1
    # decode: shard the KV cache on batch (default) or leave batch
    # replicated so cache_seq can take both mesh axes (qwen1.5-32b's
    # 40-head MHA cache does not fit otherwise)
    decode_batch_shard: bool = True
    # KV cache storage dtype: "" = model dtype; "int8" = quantized cache
    # with per-(token, head) f32 scales (qwen1.5-32b's 5.1 TiB cache is
    # 20.5 GiB/chip at bf16 — structurally over the 16 GiB budget on 256
    # chips; int8 halves it)
    kv_cache_dtype: str = ""
    # embedding tables padded up to a multiple of this so the vocab dim
    # shards (whisper's 51865 is not 16-divisible); padded logits are
    # masked in unembed
    vocab_pad_to: int = 16

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_to
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(1, self.n_kv_heads)

    def is_attention_layer(self, i: int) -> bool:
        """Hybrid interleave (Jamba 1:7 -> attn_period=8)."""
        if self.attn_period <= 0:
            return True
        return i % self.attn_period == self.attn_offset

    def is_moe_layer(self, i: int) -> bool:
        return self.moe is not None and (i % self.moe.every
                                         == self.moe.every - 1)

    def param_count(self) -> int:
        """Total parameters (embedding included), exact per family."""
        from repro.models.registry import count_params  # lazy: avoids cycle
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.registry import count_params
        return count_params(self, active_only=True)

    def scaled(self, **overrides) -> "ModelConfig":
        """Reduced copy for smoke tests (same family, tiny dims)."""
        return dataclasses.replace(self, **overrides)
