"""Dense decoder-only transformer (llama / qwen / mistral / chameleon
families) with scan-over-layers, GQA(+SWA) attention, optional QKV bias
and qk-norm, SwiGLU MLP, and an optional MoE FFN (see moe.py).

Three entry points per model:
  ``forward``        (b, l) tokens -> (b, l, v) logits        [train/prefill]
  ``forward_prefill``  also returns the populated KV cache     [serving]
  ``forward_decode``  (b, 1) token + cache -> logits + cache   [serving]
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models.config import ModelConfig
from repro.models.params import ParamDef
from repro.models.sharding import shard


# --------------------------------------------------------------- param defs
def attn_defs(cfg: ModelConfig, n: int) -> Dict[str, ParamDef]:
    d, hq, hkv, hd = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                      cfg.resolved_head_dim)
    defs: Dict[str, ParamDef] = {
        "wq": ParamDef((n, d, hq, hd), (None, "fsdp", "heads", None),
                       fan_in_dims=(1,)),
        "wk": ParamDef((n, d, hkv, hd), (None, "fsdp", "kv_heads", None),
                       fan_in_dims=(1,)),
        "wv": ParamDef((n, d, hkv, hd), (None, "fsdp", "kv_heads", None),
                       fan_in_dims=(1,)),
        "wo": ParamDef((n, hq, hd, d), (None, "heads", None, "fsdp"),
                       fan_in_dims=(1, 2)),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((n, hq, hd), (None, "heads", None),
                              init="zeros")
        defs["bk"] = ParamDef((n, hkv, hd), (None, "kv_heads", None),
                              init="zeros")
        defs["bv"] = ParamDef((n, hkv, hd), (None, "kv_heads", None),
                              init="zeros")
    if cfg.qk_norm:
        defs["q_norm"] = ParamDef((n, hd), (None, None), init="ones")
        defs["k_norm"] = ParamDef((n, hd), (None, None), init="ones")
    return defs


def mlp_defs(cfg: ModelConfig, n: int) -> Dict[str, ParamDef]:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.act == "silu":
        return {
            "w_gate": ParamDef((n, d, f), (None, "fsdp", "model"),
                               fan_in_dims=(1,)),
            "w_up": ParamDef((n, d, f), (None, "fsdp", "model"),
                             fan_in_dims=(1,)),
            "w_down": ParamDef((n, f, d), (None, "model", "fsdp"),
                               fan_in_dims=(1,)),
        }
    return {
        "w_up": ParamDef((n, d, f), (None, "fsdp", "model"), fan_in_dims=(1,)),
        "b_up": ParamDef((n, f), (None, "model"), init="zeros"),
        "w_down": ParamDef((n, f, d), (None, "model", "fsdp"),
                           fan_in_dims=(1,)),
        "b_down": ParamDef((n, d), (None, None), init="zeros"),
    }


def norm_defs(cfg: ModelConfig, n: int) -> Dict[str, ParamDef]:
    d = cfg.d_model
    defs = {"scale": ParamDef((n, d), (None, None), init="ones")}
    if cfg.norm == "layernorm":
        defs["bias"] = ParamDef((n, d), (None, None), init="zeros")
    return defs


def param_defs(cfg: ModelConfig) -> Dict[str, Any]:
    n = cfg.n_layers
    layer: Dict[str, Any] = {
        "attn": attn_defs(cfg, n),
        "attn_norm": norm_defs(cfg, n),
        "mlp_norm": norm_defs(cfg, n),
    }
    if cfg.moe is not None:
        layer["moe"] = moe_lib.moe_defs(cfg, n)
    else:
        layer["mlp"] = mlp_defs(cfg, n)
    defs: Dict[str, Any] = {
        "embed": ParamDef((cfg.padded_vocab, cfg.d_model), ("model", "fsdp"),
                          init="embed", fan_in_dims=(1,)),
        "final_norm": _unstack_norm(cfg),
        "layers": layer,
    }
    if not cfg.tie_embeddings:
        defs["unembed"] = ParamDef((cfg.padded_vocab, cfg.d_model),
                                   ("model", "fsdp"), fan_in_dims=(1,))
    return defs


def _unstack_norm(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d = cfg.d_model
    defs = {"scale": ParamDef((d,), (None,), init="ones")}
    if cfg.norm == "layernorm":
        defs["bias"] = ParamDef((d,), (None,), init="zeros")
    return defs


# --------------------------------------------------------------- layer body
def _layer(cfg: ModelConfig, x: jax.Array, w: Dict[str, Any],
           cos: jax.Array, sin: jax.Array, mask: jax.Array,
           collect_kv: bool = False):
    """Pre-norm residual block. Returns (x, aux_loss[, (k, v)])."""
    # pin the carry layout: without this GSPMD propagates whatever layout
    # the embed gather preferred into the scan carry and re-shards every
    # dot (measured: 671 MB activation all-gathers per layer, §Perf it.1)
    x = shard(x, "batch", "seq", None)
    h = L.apply_norm(cfg, x, w["attn_norm"])
    att = L.attention_block(cfg, h, w["attn"], cos, sin, mask,
                            collect_kv=collect_kv)
    kv = None
    if collect_kv:
        att, kv = att
    # fused residual-add + norm (registry residual_rmsnorm): one pass
    # produces the updated stream AND its normed view for the MLP
    x, h = L.residual_apply_norm(cfg, att, x, w["mlp_norm"])
    if "moe" in w:
        out, aux = moe_lib.moe_block(cfg, h, w["moe"])
    else:
        out, aux = L.mlp_block(cfg, h, w["mlp"]), jnp.zeros((), jnp.float32)
    if collect_kv:
        return x + out, aux, kv
    return x + out, aux


def _scan_layers(cfg: ModelConfig, x: jax.Array, layer_params: Any,
                 body) -> Tuple[jax.Array, jax.Array]:
    """lax.scan over stacked layer params with optional remat."""
    if cfg.remat == "full":
        body = jax.checkpoint(body)

    def step(carry, w):
        y, aux = body(carry, w)
        return y, aux

    x, auxs = jax.lax.scan(step, x, layer_params,
                           unroll=cfg.scan_unroll)
    return x, jnp.sum(auxs)


# --------------------------------------------------------------- forward
def forward(cfg: ModelConfig, params: Dict[str, Any], tokens: jax.Array,
            ) -> Tuple[jax.Array, jax.Array]:
    """Training forward. tokens (b, l) -> logits (b, l, v), aux."""
    b, l = tokens.shape
    x = L.embed(tokens, params["embed"]).astype(jnp.dtype(cfg.dtype))
    positions = jnp.arange(l)
    cos, sin = L.rotary_embedding(positions, cfg.resolved_head_dim,
                                  cfg.rope_theta)
    mask = L.causal_window_mask(l, l, window=cfg.sliding_window)

    body = functools.partial(_layer, cfg, cos=cos, sin=sin, mask=mask)
    x, aux = _scan_layers(cfg, x, params["layers"],
                          lambda c, w: body(c, w))
    x = L.apply_norm(cfg, x, params["final_norm"])
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return L.unembed(x, table, cfg.vocab_size), aux


def forward_prefill(cfg: ModelConfig, params: Dict[str, Any],
                    tokens: jax.Array,
                    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Serving prefill: last-position logits + populated KV cache.

    Only the final position is unembedded (the rest would be dead code in
    a real serving stack); the per-layer post-rotary K/V are stacked into
    the decode cache layout (n_layers, b, l, hkv, hd)."""
    b, l = tokens.shape
    x = L.embed(tokens, params["embed"]).astype(jnp.dtype(cfg.dtype))
    positions = jnp.arange(l)
    cos, sin = L.rotary_embedding(positions, cfg.resolved_head_dim,
                                  cfg.rope_theta)
    mask = L.causal_window_mask(l, l, window=cfg.sliding_window)

    quantized = cfg.kv_cache_dtype == "int8"

    def body(carry, w):
        y, _, (k, v) = _layer(cfg, carry, w, cos, sin, mask,
                              collect_kv=True)
        if quantized:
            kq, ks_ = L.quantize_kv(k)
            vq, vs_ = L.quantize_kv(v)
            return y, (kq, ks_, vq, vs_)
        return y, (k.astype(jnp.dtype(cfg.dtype)),
                   v.astype(jnp.dtype(cfg.dtype)))

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    x, kv_out = jax.lax.scan(body, x, params["layers"],
                             unroll=cfg.scan_unroll)
    x = L.apply_norm(cfg, x[:, -1:], params["final_norm"])
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = L.unembed(x, table, cfg.vocab_size)
    if quantized:
        kq, ks_, vq, vs_ = kv_out
        return logits, {"k": kq, "k_scale": ks_, "v": vq, "v_scale": vs_}
    ks, vs = kv_out
    return logits, {"k": ks, "v": vs}


def loss_fn(cfg: ModelConfig, params: Dict[str, Any],
            batch: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict[str, Any]]:
    logits, aux = forward(cfg, params, batch["tokens"])
    nll = L.cross_entropy(logits, batch["labels"])
    weight = cfg.moe.aux_loss_weight if cfg.moe else 0.0
    return nll + weight * aux, {"loss": nll, "aux_loss": aux}


# --------------------------------------------------------------- serving
def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype: Optional[str] = None) -> Dict[str, jax.Array]:
    """Stacked per-layer KV cache. SWA models cap the ring at the window;
    ``cfg.kv_cache_dtype == 'int8'`` stores quantized K/V with
    per-(token, head) f32 scales (layers.quantize_kv)."""
    if cfg.sliding_window is not None:
        max_seq = min(max_seq, cfg.sliding_window)
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads,
             cfg.resolved_head_dim)
    if cfg.kv_cache_dtype == "int8":
        sshape = shape[:-1]
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(sshape, jnp.float32),
                "v_scale": jnp.zeros(sshape, jnp.float32)}
    dt = jnp.dtype(dtype or cfg.dtype)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int, rules,
                dtype: Optional[str] = None) -> Dict[str, Any]:
    from jax.sharding import PartitionSpec as P
    if cfg.sliding_window is not None:
        max_seq = min(max_seq, cfg.sliding_window)
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads,
             cfg.resolved_head_dim)
    axes = (None, "batch", "cache_seq", None, None)
    spec = P() if rules is None else rules.spec(axes, shape)
    out = {"k": spec, "v": spec}
    if cfg.kv_cache_dtype == "int8":
        sspec = (P() if rules is None
                 else rules.spec(axes[:-1], shape[:-1]))
        out["k_scale"] = sspec
        out["v_scale"] = sspec
    return out


def forward_decode(cfg: ModelConfig, params: Dict[str, Any],
                   token: jax.Array, cache: Dict[str, jax.Array],
                   index: jax.Array) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step. token (b, 1); cache leaves (n_layers, ...)."""
    x = L.embed(token, params["embed"]).astype(jnp.dtype(cfg.dtype))
    keys = sorted(cache)  # k, k_scale?, v, v_scale?

    def body(carry, xs):
        w = xs[0]
        layer_cache = dict(zip(keys, xs[1:]))
        h = L.apply_norm(cfg, carry, w["attn_norm"])
        att, new_cache = L.decode_attention_block(
            cfg, h, w["attn"], layer_cache, index)
        y = carry + att
        h = L.apply_norm(cfg, y, w["mlp_norm"])
        if "moe" in w:
            out, _ = moe_lib.moe_block(cfg, h, w["moe"])
        else:
            out = L.mlp_block(cfg, h, w["mlp"])
        return y + out, tuple(new_cache[k] for k in keys)

    xs = (params["layers"],) + tuple(cache[k] for k in keys)
    x, new_leaves = jax.lax.scan(body, x, xs, unroll=cfg.scan_unroll)
    x = L.apply_norm(cfg, x, params["final_norm"])
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return (L.unembed(x, table, cfg.vocab_size),
            dict(zip(keys, new_leaves)))
