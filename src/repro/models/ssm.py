"""State-space / recurrent families: xLSTM (mLSTM + sLSTM) and Mamba (S6).

TPU adaptation notes (see DESIGN.md §3):
  * mLSTM trains with the stabilized *parallel* (quadratic) form — an
    attention-shaped einsum that maps onto the MXU — and decodes with the
    O(1) matrix-memory recurrence.
  * Mamba's selective scan goes through ``repro.kernels.registry.ssm_scan``
    (dispatched by ``cfg.kernels``): the Pallas kernel streams the state
    through VMEM on TPU; the XLA_ASSOCIATIVE variant is the *chunked
    associative scan* — parallel within chunks
    (``jax.lax.associative_scan``), sequential across chunk boundaries
    (``lax.scan`` carry) — which bounds the materialized state to
    (chunk, d_inner, d_state) instead of (L, d_inner, d_state).
  * sLSTM is inherently sequential (true recurrence on the hidden state);
    it runs as ``lax.scan`` over time.  This does not parallelize over
    the sequence — an acknowledged property of the architecture, noted in
    the xLSTM paper itself.

Decode state (per layer) is the analogue of a KV cache:
  mLSTM: C (b,h,d,d), n (b,h,d), m (b,h)
  sLSTM: c,n,h̃ (b,h,d) + m (b,h)
  Mamba: conv tail (b, d_conv-1, d_inner) + ssm state (b, d_inner, d_state)
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import registry as K
from repro.models.config import ModelConfig
from repro.models.params import ParamDef
from repro.models.sharding import shard


# ====================================================================== mLSTM
def mlstm_defs(cfg: ModelConfig, n: int) -> Dict[str, ParamDef]:
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    return {
        "w_in": ParamDef((n, d, 2 * d), (None, "fsdp", "model"),
                         fan_in_dims=(1,)),            # x branch + gate
        "wq": ParamDef((n, d, h, hd), (None, "fsdp", "model", None),
                       fan_in_dims=(1,)),
        "wk": ParamDef((n, d, h, hd), (None, "fsdp", "model", None),
                       fan_in_dims=(1,)),
        "wv": ParamDef((n, d, h, hd), (None, "fsdp", "model", None),
                       fan_in_dims=(1,)),
        "w_if": ParamDef((n, d, 2 * h), (None, "fsdp", None),
                         fan_in_dims=(1,)),            # input+forget gates
        "b_if": ParamDef((n, 2 * h), (None, None), init="zeros"),
        "w_out": ParamDef((n, d, d), (None, "model", "fsdp"),
                          fan_in_dims=(1,)),
    }


def _mlstm_parallel(q: jax.Array, k: jax.Array, v: jax.Array,
                    i_gate: jax.Array, f_gate: jax.Array) -> jax.Array:
    """Stabilized parallel mLSTM (xLSTM paper eq. 19-27).

    q/k/v (b, l, h, d);  i/f (b, l, h) pre-activations.
    """
    b, l, h, d = q.shape
    logf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))       # (b,l,h)
    cum = jnp.cumsum(logf, axis=1)
    # F[t,s] = cum[t] - cum[s]  (decay applied strictly after step s)
    fmat = cum[:, :, None, :] - cum[:, None, :, :]              # (b,t,s,h)
    dmat = fmat + i_gate.astype(jnp.float32)[:, None, :, :]     # + i[s]
    tri = jnp.tril(jnp.ones((l, l), bool))
    dmat = jnp.where(tri[None, :, :, None], dmat, -jnp.inf)
    m = jnp.max(dmat, axis=2, keepdims=True)                    # (b,t,1,h)
    dexp = jnp.exp(dmat - m)                                    # stabilized
    scores = jnp.einsum("blhd,bshd->blsh", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(d) * dexp
    norm = jnp.maximum(jnp.abs(jnp.sum(scores, axis=2)),
                       jnp.exp(-m[:, :, 0, :]))                 # (b,l,h)
    out = jnp.einsum("blsh,bshd->blhd", scores.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return (out / norm[..., None]).astype(v.dtype)


def mlstm_block(cfg: ModelConfig, x: jax.Array, w: Dict[str, Any]) -> jax.Array:
    b, l, d = x.shape
    h = cfg.n_heads
    hd = d // h
    inner = jnp.einsum("bld,de->ble", x, w["w_in"])
    xin, gate = jnp.split(inner, 2, axis=-1)
    q = jnp.einsum("bld,dhk->blhk", xin, w["wq"])
    k = jnp.einsum("bld,dhk->blhk", xin, w["wk"]) / math.sqrt(hd)
    v = jnp.einsum("bld,dhk->blhk", xin, w["wv"])
    gates = jnp.einsum("bld,dg->blg", xin, w["w_if"]) + w["b_if"]
    i_gate, f_gate = jnp.split(gates, 2, axis=-1)
    out = _mlstm_parallel(q, k, v, i_gate, f_gate)
    out = out.reshape(b, l, d) * jax.nn.silu(gate.astype(jnp.float32)
                                             ).astype(x.dtype)
    return jnp.einsum("bld,de->ble", out, w["w_out"])


def mlstm_decode(cfg: ModelConfig, x: jax.Array, w: Dict[str, Any],
                 state: Dict[str, jax.Array],
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x (b, 1, d); state C (b,h,d,d), n (b,h,d), m (b,h)."""
    b, _, d = x.shape
    h = cfg.n_heads
    hd = d // h
    inner = jnp.einsum("bld,de->ble", x, w["w_in"])
    xin, gate = jnp.split(inner, 2, axis=-1)
    q = jnp.einsum("bd,dhk->bhk", xin[:, 0], w["wq"])
    k = jnp.einsum("bd,dhk->bhk", xin[:, 0], w["wk"]) / math.sqrt(hd)
    v = jnp.einsum("bd,dhk->bhk", xin[:, 0], w["wv"])
    gates = jnp.einsum("bd,dg->bg", xin[:, 0], w["w_if"]) + w["b_if"]
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)                 # (b, h)
    i_pre = i_pre.astype(jnp.float32)
    logf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    m_new = jnp.maximum(logf + state["m"], i_pre)
    a = jnp.exp(logf + state["m"] - m_new)                      # (b, h)
    bb = jnp.exp(i_pre - m_new)
    kf, vf, qf = (t.astype(jnp.float32) for t in (k, v, q))
    c_new = (a[..., None, None] * state["C"]
             + bb[..., None, None] * kf[..., :, None] * vf[..., None, :])
    n_new = a[..., None] * state["n"] + bb[..., None] * kf
    num = jnp.einsum("bhkd,bhk->bhd", c_new, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, qf)),
                      jnp.exp(-m_new))
    out = (num / den[..., None]).reshape(b, 1, d).astype(x.dtype)
    out = out * jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype)
    return (jnp.einsum("bld,de->ble", out, w["w_out"]),
            {"C": c_new, "n": n_new, "m": m_new})


# ====================================================================== sLSTM
def slstm_defs(cfg: ModelConfig, n: int) -> Dict[str, ParamDef]:
    d = cfg.d_model
    return {
        # 4 gates (z, i, f, o), input + per-head recurrent weights
        "w_x": ParamDef((n, d, 4 * d), (None, "fsdp", "model"),
                        fan_in_dims=(1,)),
        "w_h": ParamDef((n, cfg.n_heads, d // cfg.n_heads, 4 * d // cfg.n_heads),
                        (None, "model", None, None), fan_in_dims=(2,)),
        "bias": ParamDef((n, 4 * d), (None, "model"), init="zeros"),
        "w_out": ParamDef((n, d, d), (None, "model", "fsdp"),
                          fan_in_dims=(1,)),
    }


def _slstm_cell(carry, gx, head_dim):
    """One timestep. carry: (c, n, h, m) each (b, H, hd) / m (b, H)."""
    c, n, h, m = carry
    # gx (b, H, 4*hd) = W_x·x_t (+ bias); add recurrent term outside
    z_pre, i_pre, f_pre, o_pre = jnp.split(gx, 4, axis=-1)
    # exponential gating with stabilizer state m (scalar per head)
    i_max = jnp.max(i_pre, axis=-1)
    logf = jax.nn.log_sigmoid(jnp.mean(f_pre, axis=-1))        # (b, H)
    m_new = jnp.maximum(logf + m, i_max)
    i_g = jnp.exp(i_pre - m_new[..., None])
    f_g = jnp.exp(logf + m - m_new)[..., None]
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    c_new = f_g * c + i_g * z
    n_new = f_g * n + i_g
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new, m_new)


def slstm_block(cfg: ModelConfig, x: jax.Array, w: Dict[str, Any]) -> jax.Array:
    """Sequential scan over time; block-diagonal (per-head) recurrence."""
    b, l, d = x.shape
    H = cfg.n_heads
    hd = d // H
    gx_all = (jnp.einsum("bld,dg->blg", x, w["w_x"]) + w["bias"]
              ).astype(jnp.float32).reshape(b, l, H, 4 * hd)

    def step(carry, gx_t):
        c, n, h, m = carry
        rec = jnp.einsum("bhk,hkg->bhg", h, w["w_h"].astype(jnp.float32))
        new = _slstm_cell((c, n, h, m), gx_t + rec, hd)
        return new, new[2]

    zeros = jnp.zeros((b, H, hd), jnp.float32)
    m0 = jnp.full((b, H), -1e30, jnp.float32)
    (_, _, _, _), hs = jax.lax.scan(step, (zeros, zeros, zeros, m0),
                                    gx_all.transpose(1, 0, 2, 3))
    out = hs.transpose(1, 0, 2, 3).reshape(b, l, d).astype(x.dtype)
    return jnp.einsum("bld,de->ble", out, w["w_out"])


def slstm_decode(cfg: ModelConfig, x: jax.Array, w: Dict[str, Any],
                 state: Dict[str, jax.Array],
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    b, _, d = x.shape
    H = cfg.n_heads
    hd = d // H
    gx = (jnp.einsum("bd,dg->bg", x[:, 0], w["w_x"]) + w["bias"]
          ).astype(jnp.float32).reshape(b, H, 4 * hd)
    rec = jnp.einsum("bhk,hkg->bhg", state["h"], w["w_h"].astype(jnp.float32))
    c, n, h, m = _slstm_cell((state["c"], state["n"], state["h"], state["m"]),
                             gx + rec, hd)
    out = h.reshape(b, 1, d).astype(x.dtype)
    return (jnp.einsum("bld,de->ble", out, w["w_out"]),
            {"c": c, "n": n, "h": h, "m": m})


# ====================================================================== Mamba
def mamba_defs(cfg: ModelConfig, n: int) -> Dict[str, ParamDef]:
    d = cfg.d_model
    di = cfg.expand * d
    ds = cfg.d_state
    dt_rank = max(1, d // 16)
    return {
        "w_in": ParamDef((n, d, 2 * di), (None, "fsdp", "model"),
                         fan_in_dims=(1,)),
        "conv_w": ParamDef((n, cfg.d_conv, di), (None, None, "model"),
                           scale=1.0, fan_in_dims=(1,)),
        "conv_b": ParamDef((n, di), (None, "model"), init="zeros"),
        "w_bcdt": ParamDef((n, di, 2 * ds + dt_rank), (None, "model", None),
                           fan_in_dims=(1,)),
        "dt_proj": ParamDef((n, dt_rank, di), (None, None, "model"),
                            fan_in_dims=(1,)),
        "dt_bias": ParamDef((n, di), (None, "model"), init="zeros"),
        "a_log": ParamDef((n, di, ds), (None, "model", None), init="ones"),
        "d_skip": ParamDef((n, di), (None, "model"), init="ones"),
        "w_out": ParamDef((n, di, d), (None, "model", "fsdp"),
                          fan_in_dims=(1,)),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 tail: jax.Array = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. x (b, l, di), w (k, di). Returns (y, new_tail)."""
    k = w.shape[0]
    pad = tail if tail is not None else jnp.zeros(
        (x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k)) + b
    new_tail = xp[:, -(k - 1):, :] if k > 1 else pad
    return y.astype(x.dtype), new_tail


def mamba_block(cfg: ModelConfig, x: jax.Array, w: Dict[str, Any],
                ) -> jax.Array:
    b, l, d = x.shape
    di = cfg.expand * d
    ds = cfg.d_state
    xin, z = jnp.split(jnp.einsum("bld,de->ble", x, w["w_in"]), 2, axis=-1)
    xin = shard(xin, "batch", None, "model")
    xc, _ = _causal_conv(xin, w["conv_w"], w["conv_b"])
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    bcdt = jnp.einsum("bld,dg->blg", xc, w["w_bcdt"])
    bmat, cmat, dt = jnp.split(bcdt.astype(jnp.float32),
                               [ds, 2 * ds], axis=-1)
    delta = jax.nn.softplus(
        jnp.einsum("blr,rd->bld", dt, w["dt_proj"].astype(jnp.float32))
        + w["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(w["a_log"].astype(jnp.float32))
    h0 = jnp.zeros((b, di, ds), jnp.float32)
    chunk = cfg.mamba_chunk if cfg.mamba_chunk > 0 else l
    # selective scan via the kernel registry (Pallas on TPU; chunked
    # associative scan as the XLA formulation — see kernels/registry.py)
    y, _ = K.ssm_scan(xc.astype(jnp.float32), delta, a, bmat, cmat, h0,
                      chunk=chunk, kernels=cfg.kernels)
    y = y + xc.astype(jnp.float32) * w["d_skip"].astype(jnp.float32)
    y = (y.astype(x.dtype)
         * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype))
    y = shard(y, "batch", None, "model")
    return jnp.einsum("bld,de->ble", y, w["w_out"])


def mamba_decode(cfg: ModelConfig, x: jax.Array, w: Dict[str, Any],
                 state: Dict[str, jax.Array],
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x (b, 1, d); state: conv_tail (b, k-1, di), h (b, di, ds)."""
    b, _, d = x.shape
    ds = cfg.d_state
    xin, z = jnp.split(jnp.einsum("bld,de->ble", x, w["w_in"]), 2, axis=-1)
    xc, new_tail = _causal_conv(xin, w["conv_w"], w["conv_b"],
                                tail=state["conv"])
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    bcdt = jnp.einsum("bld,dg->blg", xc, w["w_bcdt"])
    bmat, cmat, dt = jnp.split(bcdt.astype(jnp.float32),
                               [ds, 2 * ds], axis=-1)
    delta = jax.nn.softplus(
        jnp.einsum("blr,rd->bld", dt, w["dt_proj"].astype(jnp.float32))
        + w["dt_bias"].astype(jnp.float32))                    # (b,1,di)
    a = -jnp.exp(w["a_log"].astype(jnp.float32))
    abar = jnp.exp(delta[..., None] * a[None, None])[:, 0]     # (b,di,ds)
    bbar = (delta[..., None] * bmat[:, :, None, :]
            * xc.astype(jnp.float32)[..., None])[:, 0]
    h = abar * state["h"] + bbar
    y = jnp.einsum("bds,bs->bd", h, cmat[:, 0])
    y = y + xc[:, 0].astype(jnp.float32) * w["d_skip"].astype(jnp.float32)
    y = (y[:, None].astype(x.dtype)
         * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype))
    return (jnp.einsum("bld,de->ble", y, w["w_out"]),
            {"conv": new_tail, "h": h})


# =============================================================== xLSTM LM
def xlstm_param_defs(cfg: ModelConfig) -> Dict[str, Any]:
    """xLSTM[m:s] language model: mLSTM blocks with sLSTM at
    ``cfg.slstm_layers`` (unrolled — 12 layers, HLO stays small)."""
    n_s = len(cfg.slstm_layers)
    n_m = cfg.n_layers - n_s
    d = cfg.d_model
    defs: Dict[str, Any] = {
        "embed": ParamDef((cfg.padded_vocab, d), ("model", "fsdp"),
                          init="embed", fan_in_dims=(1,)),
        "final_norm": {"scale": ParamDef((d,), (None,), init="ones")},
        "mlstm": mlstm_defs(cfg, n_m),
        "mlstm_norm": {"scale": ParamDef((n_m, d), (None, None), init="ones")},
    }
    if n_s:
        defs["slstm"] = slstm_defs(cfg, n_s)
        defs["slstm_norm"] = {"scale": ParamDef((n_s, d), (None, None),
                                                init="ones")}
    if not cfg.tie_embeddings:
        defs["unembed"] = ParamDef((cfg.padded_vocab, d), ("model", "fsdp"),
                                   fan_in_dims=(1,))
    return defs


def _xlstm_layer_plan(cfg: ModelConfig):
    """[(kind, index-within-kind)] per layer."""
    plan, im, is_ = [], 0, 0
    for i in range(cfg.n_layers):
        if i in cfg.slstm_layers:
            plan.append(("slstm", is_)); is_ += 1
        else:
            plan.append(("mlstm", im)); im += 1
    return plan


def _slice_layer(tree: Any, i: int) -> Any:
    return jax.tree_util.tree_map(lambda x: x[i], tree)


def xlstm_forward(cfg: ModelConfig, params: Dict[str, Any],
                  tokens: jax.Array) -> Tuple[jax.Array, jax.Array]:
    from repro.models import layers as L
    x = L.embed(tokens, params["embed"]).astype(jnp.dtype(cfg.dtype))
    for kind, j in _xlstm_layer_plan(cfg):
        w = _slice_layer(params[kind], j)
        nrm = _slice_layer(params[f"{kind}_norm"], j)
        blk = mlstm_block if kind == "mlstm" else slstm_block

        def layer_fn(y, w_=w, nrm_=nrm, blk_=blk):
            return y + blk_(cfg, L.rms_norm(y, nrm_["scale"],
                                            kernels=cfg.kernels), w_)

        if cfg.remat == "full":
            # per-layer remat: the mLSTM parallel form materializes an
            # (l x l) decay/score block per layer -- without remat the
            # unrolled 12-layer backward keeps all of them live
            layer_fn = jax.checkpoint(layer_fn)
        x = layer_fn(x)
    x = L.rms_norm(x, params["final_norm"]["scale"], kernels=cfg.kernels)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return L.unembed(x, table, cfg.vocab_size), jnp.zeros((), jnp.float32)


def xlstm_init_state(cfg: ModelConfig, batch: int) -> Dict[str, Any]:
    d = cfg.d_model
    H = cfg.n_heads
    hd = d // H
    n_s = len(cfg.slstm_layers)
    n_m = cfg.n_layers - n_s
    state: Dict[str, Any] = {
        "mlstm": {
            "C": jnp.zeros((n_m, batch, H, hd, hd), jnp.float32),
            "n": jnp.zeros((n_m, batch, H, hd), jnp.float32),
            "m": jnp.full((n_m, batch, H), -1e30, jnp.float32),
        }
    }
    if n_s:
        z = jnp.zeros((n_s, batch, H, hd), jnp.float32)
        state["slstm"] = {"c": z, "n": z, "h": z,
                          "m": jnp.full((n_s, batch, H), -1e30, jnp.float32)}
    return state


def xlstm_decode(cfg: ModelConfig, params: Dict[str, Any], token: jax.Array,
                 state: Dict[str, Any], index: jax.Array,
                 ) -> Tuple[jax.Array, Dict[str, Any]]:
    from repro.models import layers as L
    x = L.embed(token, params["embed"]).astype(jnp.dtype(cfg.dtype))
    new_state = jax.tree_util.tree_map(lambda v: v, state)  # shallow copy
    for kind, j in _xlstm_layer_plan(cfg):
        w = _slice_layer(params[kind], j)
        nrm = _slice_layer(params[f"{kind}_norm"], j)
        h = L.rms_norm(x, nrm["scale"], kernels=cfg.kernels)
        st = _slice_layer(state[kind], j)
        if kind == "mlstm":
            out, st2 = mlstm_decode(cfg, h, w, st)
        else:
            out, st2 = slstm_decode(cfg, h, w, st)
        x = x + out
        for key, val in st2.items():
            new_state[kind][key] = new_state[kind][key].at[j].set(val)
    x = L.rms_norm(x, params["final_norm"]["scale"], kernels=cfg.kernels)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return L.unembed(x, table, cfg.vocab_size), new_state
