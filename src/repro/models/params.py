"""Declarative parameter trees.

Each architecture declares its weights once as a pytree of ``ParamDef``
(shape + logical sharding axes + initializer).  From that single
declaration we derive:

  * ``init_tree``  — materialized, randomly initialized arrays (smoke
    tests, real training),
  * ``spec_tree``  — ``PartitionSpec`` pytree for pjit in/out shardings
    (size-aware: non-dividing mappings drop, see sharding.py),
  * ``sds_tree``   — ``ShapeDtypeStruct`` stand-ins (dry-run: no
    allocation at 123B scale),
  * ``count``      — exact parameter counts (roofline MODEL_FLOPS).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.sharding import AxisRules


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]       # logical axis per dim
    init: str = "normal"                  # normal | zeros | ones | embed
    scale: float = 1.0                    # stddev multiplier for 'normal'
    fan_in_dims: Tuple[int, ...] = ()     # dims whose product is fan-in
    dtype: Optional[str] = None           # override model dtype

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"rank mismatch {self.shape} vs {self.axes}")

    def stddev(self) -> float:
        fan_in = 1
        for d in self.fan_in_dims:
            fan_in *= self.shape[d]
        return self.scale / math.sqrt(max(1, fan_in))


def _is_def(x: Any) -> bool:
    return isinstance(x, ParamDef)


def _map_defs(fn: Callable[[ParamDef], Any], tree: Any) -> Any:
    return jax.tree_util.tree_map(fn, tree, is_leaf=_is_def)


def init_tree(defs: Any, key: jax.Array, default_dtype: str) -> Any:
    leaves = [d for d in jax.tree_util.tree_leaves(defs, is_leaf=_is_def)]
    keys = iter(jax.random.split(key, max(1, len(leaves))))

    def make(d: ParamDef) -> jax.Array:
        dt = jnp.dtype(d.dtype or default_dtype)
        k = next(keys)
        if d.init == "zeros":
            return jnp.zeros(d.shape, dt)
        if d.init == "ones":
            return jnp.ones(d.shape, dt)
        if d.init in ("normal", "embed"):
            return (jax.random.normal(k, d.shape, jnp.float32)
                    * d.stddev()).astype(dt)
        raise ValueError(f"unknown init {d.init!r}")

    return _map_defs(make, defs)


def spec_tree(defs: Any, rules: Optional[AxisRules]) -> Any:
    from jax.sharding import PartitionSpec as P

    def spec(d: ParamDef):
        return P() if rules is None else rules.spec(d.axes, d.shape)

    return _map_defs(spec, defs)


def sds_tree(defs: Any, default_dtype: str) -> Any:
    def sds(d: ParamDef):
        return jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype or default_dtype))

    return _map_defs(sds, defs)


def count(defs: Any) -> int:
    total = 0
    for d in jax.tree_util.tree_leaves(defs, is_leaf=_is_def):
        total += math.prod(d.shape)
    return total


def named_subtree_counts(defs: Any) -> Dict[str, int]:
    """Top-level-key -> param count (DESIGN/EXPERIMENTS reporting)."""
    out = {}
    for k, sub in defs.items():
        out[k] = count(sub)
    return out
