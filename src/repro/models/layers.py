"""Shared neural building blocks.

The worker-step hot ops (attention, RMSNorm, fused residual+RMSNorm)
route through ``repro.kernels.registry`` — enum dispatch over
Pallas/XLA variants selected by ``cfg.kernels`` — on the unsharded
path; the mesh-sharded SP/TP formulations below stay XLA (their layout
pins are the point, see EXPERIMENTS.md §Perf).

Conventions:
  activations   (batch, seq, d_model)                 bf16/f32
  q/k/v         (batch, seq, heads, head_dim)
  KV cache      (batch, max_seq, kv_heads, head_dim)  — 'cache_seq' sharded
  softmax/norm accumulation always float32.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import registry as K
from repro.models.config import ModelConfig
from repro.models.sharding import shard

NEG_INF = -1e30  # large-but-finite: -inf breaks softmax rows that are fully masked


# ----------------------------------------------------------------- norms
def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6,
             kernels: str = "auto") -> jax.Array:
    """Registry-dispatched RMSNorm (``kernels`` = ``cfg.kernels``)."""
    return K.rmsnorm(x, weight, eps=eps, kernels=kernels)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


def apply_norm(cfg: ModelConfig, x: jax.Array, w: Dict[str, jax.Array]) -> jax.Array:
    if cfg.norm == "rmsnorm":
        return rms_norm(x, w["scale"], kernels=cfg.kernels)
    return layer_norm(x, w["scale"], w["bias"])


def residual_apply_norm(cfg: ModelConfig, delta: jax.Array, x: jax.Array,
                        w: Dict[str, jax.Array],
                        ) -> Tuple[jax.Array, jax.Array]:
    """Pre-norm block glue: ``(x + delta, norm(x + delta))``.

    For rmsnorm this is the registry's fused residual+RMSNorm op (one
    VMEM pass on the Pallas variant); layernorm keeps the unfused form.
    """
    if cfg.norm == "rmsnorm":
        return K.residual_rmsnorm(delta, x, w["scale"], kernels=cfg.kernels)
    s = x + delta
    return s, layer_norm(s, w["scale"], w["bias"])


# ----------------------------------------------------------------- rotary
def rotary_embedding(positions: jax.Array, head_dim: int,
                     theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions (...,) -> cos/sin (..., head_dim/2), float32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rotary(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (b, s, h, d); cos/sin (b, s, d/2) or (s, d/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:           # (s, d/2) -> broadcast over batch/heads
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    else:                       # (b, s, d/2)
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- attention
def causal_window_mask(lq: int, lk: int, *, q_offset: int = 0,
                       window: Optional[int] = None) -> jax.Array:
    """(lq, lk) bool mask: True = attend. Causal plus optional sliding
    window of width ``window`` (inclusive of self)."""
    qpos = jnp.arange(lq)[:, None] + q_offset
    kpos = jnp.arange(lk)[None, :]
    mask = kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    return mask


def _attention_grouped(q: jax.Array, k: jax.Array, v: jax.Array,
                       mask: jax.Array, pin=None) -> jax.Array:
    """Grouped GQA: q (b, lq, hkv, g, d); k/v (b, lk, hkv, d).

    Used for decode (lq == 1: tiny scores; the KV cache keeps its own
    cache_seq sharding) and for SP-mode training (scores
    (b, hkv, g, lq, lk) pinned seq-sharded on lq via ``pin``)."""
    d = q.shape[-1]
    scores = jnp.einsum("blhgd,bmhd->bhglm", q, k,
                        preferred_element_type=jnp.float32) / math.sqrt(d)
    if pin is not None:
        scores = shard(scores, *pin)
    if mask.ndim == 2:
        m = mask[None, None, None, :, :]
    else:
        m = mask[:, None, None, :, :]
    scores = jnp.where(m, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    if pin is not None:
        probs = shard(probs, *pin)
    out = jnp.einsum("bhglm,bmhd->blhgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def _attention_heads(q: jax.Array, k: jax.Array, v: jax.Array,
                     mask: jax.Array) -> jax.Array:
    """Train-path attention over full query heads: q/k/v (b, l, hq, d).

    KV were pre-broadcast to hq heads so the (lq × lk) score tensor
    shards on the head axis — the GQA (hkv, g) factored form would cap
    score sharding at hkv (< mesh 'model' size for most assigned archs)
    and GSPMD would materialize near-replicated multi-GiB score blocks.

    The score/prob/out layouts are pinned (heads over 'model', query-seq
    fallback): measured on the dry-run, leaving them to GSPMD's choice
    produced l-sharded scores plus 671 MB q/k head-gathers per layer
    (§Perf iteration 2).
    """
    d = q.shape[-1]
    scores = jnp.einsum("blhd,bmhd->bhlm", q, k,
                        preferred_element_type=jnp.float32) / math.sqrt(d)
    scores = _shard_scores(scores)
    m = mask[None, None] if mask.ndim == 2 else mask[:, None]
    scores = jnp.where(m, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = _shard_scores(probs)
    out = jnp.einsum("bhlm,bmhd->blhd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    out = shard_attn_q(out)
    return out.astype(q.dtype)


def _shard_scores(s: jax.Array) -> jax.Array:
    """scores/probs (b, h, lq, lk): heads over 'model' when divisible,
    else query-seq over 'model' (the shard_attn_q fallback layout)."""
    from repro.models.sharding import current_rules
    r = current_rules()
    if r is None or r.mesh is None:
        return s
    spec = r.spec(("batch", "model", None, None), s.shape)
    if "model" not in jax.tree_util.tree_leaves(spec):
        spec = r.spec(("batch", None, "model", None), s.shape)
    return jax.lax.with_sharding_constraint(
        s, jax.sharding.NamedSharding(r.mesh, spec))


def attention(cfg: ModelConfig, q: jax.Array, k: jax.Array, v: jax.Array,
              *, mask: jax.Array,
              causal_structure: Optional[Tuple[bool, Optional[int]]] = None,
              ) -> jax.Array:
    """Grouped-query attention.

    q (b, lq, hq, d); k/v (b, lk, hkv, d); mask (lq, lk) or (b, lq, lk).
    Returns (b, lq, hq, d).  ``causal_structure`` = (causal, window)
    asserts that ``mask`` is exactly ``causal_window_mask(lq, lk,
    q_offset=lk-lq, window=window)`` — the structured form the kernel
    registry can dispatch on.

    Four paths:
      * decode (lq == 1): grouped (hkv, g) form, tiny scores (XLA);
      * unsharded (no mesh) with a structured mask: dispatched through
        ``repro.kernels.registry.attention`` per ``cfg.kernels`` — the
        Pallas flash kernel (kernels/flash_attention.py, native on TPU,
        interpret mode elsewhere) or the quadratic XLA formulation;
      * SP mode (sequence-parallel attention — the measured default,
        EXPERIMENTS.md §Perf it.9): queries/scores/outputs stay
        seq-sharded over 'model', heads unsharded, K/V gathered to full
        length (small: hkv heads) — no l<->h layout transitions and no
        wo psum;
      * TP mode: KV broadcast to hq heads so scores shard on heads; when
        ``cfg.attn_chunk`` divides lq, queries go through a lax.scan in
        chunks — same math with (chunk × lk) score blocks bounding live
        memory, but NOT an online softmax: each chunk still materializes
        its full score rows (that fusion is the registry's Pallas flash
        variant, which the sharded paths do not use).
    """
    b, lq, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    if lq == 1:
        out = _attention_grouped(q.reshape(b, lq, hkv, g, d), k, v, mask)
        return out.reshape(b, lq, hq, d)

    if causal_structure is not None and not _has_mesh() and mask.ndim == 2:
        causal, window = causal_structure
        return K.attention(q, k, v, causal=causal, window=window,
                           kernels=cfg.kernels)

    if _sp_mode():
        # k/v gathered over l (they arrive seq-sharded), heads unsharded
        k = shard(k, "batch", None, None, None)
        v = shard(v, "batch", None, None, None)
        qg = shard(q, "batch", "seq", None, None).reshape(b, lq, hkv, g, d)
        out = _attention_grouped(qg, k, v, mask,
                                 pin=("batch", None, None, "seq", None))
        return shard(out.reshape(b, lq, hq, d), "batch", "seq", None, None)

    # TP mode: k/v gathered over l BEFORE the head broadcast -- otherwise
    # GSPMD hits "involuntary full rematerialization" resharding the
    # broadcast output (measured on qwen110, see EXPERIMENTS.md #Perf)
    k = shard(k, "batch", None, None, None)
    v = shard(v, "batch", None, None, None)
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    k = shard_attn_kv(k)
    v = shard_attn_kv(v)

    chunk = cfg.attn_chunk
    if mask.ndim != 2 or chunk <= 0 or lq <= chunk or lq % chunk:
        return _attention_heads(q, k, v, mask)

    n_chunks = lq // chunk
    q_chunks = q.reshape(b, n_chunks, chunk, hq, d).transpose(1, 0, 2, 3, 4)
    m_chunks = mask.reshape(n_chunks, chunk, mask.shape[1])

    def body(_, xs):
        qc, mc = xs
        return (), _attention_heads(qc, k, v, mc)

    _, out = jax.lax.scan(body, (), (q_chunks, m_chunks))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, lq, hq, d)


def _sp_mode() -> bool:
    from repro.models.sharding import current_rules
    r = current_rules()
    return r is not None and getattr(r, "attn_mode", "tp") == "sp"


def _has_mesh() -> bool:
    from repro.models.sharding import current_rules
    r = current_rules()
    return r is not None and r.mesh is not None


def shard_attn_q(x: jax.Array) -> jax.Array:
    """q (b, l, hq, d): SP mode -> seq-sharded; TP mode -> heads over
    'model' when divisible, else fall back to sequence(-query) sharding
    (qwen1.5-32b's 40 heads, whisper's 6 heads — DESIGN.md §6)."""
    from repro.models.sharding import current_rules
    r = current_rules()
    if r is None or r.mesh is None:
        return x
    if getattr(r, "attn_mode", "tp") == "sp":
        spec = r.spec(("batch", "seq", None, None), x.shape)
    else:
        spec = r.spec(("batch", None, "model", None), x.shape)
        if "model" not in jax.tree_util.tree_leaves(spec):
            spec = r.spec(("batch", "model", None, None), x.shape)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(r.mesh, spec))


def shard_attn_kv(x: jax.Array) -> jax.Array:
    """k/v post-broadcast (b, l, hq, d): heads over 'model' when they
    divide; otherwise replicated (queries carry the seq sharding)."""
    from repro.models.sharding import current_rules
    r = current_rules()
    if r is None or r.mesh is None:
        return x
    spec = r.spec(("batch", None, "model", None), x.shape)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(r.mesh, spec))


def attention_block(cfg: ModelConfig, x: jax.Array, w: Dict[str, Any],
                    cos: jax.Array, sin: jax.Array,
                    mask: jax.Array, *, collect_kv: bool = False):
    """Full self-attention sublayer (training / prefill path).

    ``mask`` contract: every caller passes ``causal_window_mask(l, l,
    window=cfg.sliding_window)`` — asserted structurally to
    ``attention`` so the kernel registry can dispatch the flash
    variant.  With ``collect_kv`` also returns the post-rotary (k, v) —
    the prefill path stacks them into the serving KV cache."""
    b, l, _ = x.shape
    q = jnp.einsum("bld,dhk->blhk", x, w["wq"])
    k = jnp.einsum("bld,dhk->blhk", x, w["wk"])
    v = jnp.einsum("bld,dhk->blhk", x, w["wv"])
    if cfg.qkv_bias:
        q = q + w["bq"]
        k = k + w["bk"]
        v = v + w["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, w["q_norm"], kernels=cfg.kernels)
        k = rms_norm(k, w["k_norm"], kernels=cfg.kernels)
    if cfg.use_rope:
        q = apply_rotary(q, cos, sin)
        k = apply_rotary(k, cos, sin)
    q = shard_attn_q(q)
    out = attention(cfg, q, k, v, mask=mask,
                    causal_structure=(True, cfg.sliding_window))
    out = jnp.einsum("blhk,hkd->bld", out, w["wo"])
    out = shard(out, "batch", None, None)
    if collect_kv:
        return out, (k, v)
    return out


def quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x (..., hd) -> (int8 values, f32 per-row scale). Symmetric."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1), 1e-8) / 127.0
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_kv(q: jax.Array, scale: jax.Array,
                  dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def decode_attention_block(cfg: ModelConfig, x: jax.Array, w: Dict[str, Any],
                           cache: Dict[str, jax.Array], index: jax.Array,
                           ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode with in-place KV cache update.

    x (b, 1, d); cache {'k','v'} (b, S, hkv, hd) + ring semantics when the
    config has a sliding window smaller than S.  With
    ``cfg.kv_cache_dtype == 'int8'`` the cache carries quantized values
    plus per-(token, head) scales ('k_scale'/'v_scale', (b, S, hkv)).
    """
    b = x.shape[0]
    s_max = cache["k"].shape[1]
    q = jnp.einsum("bld,dhk->blhk", x, w["wq"])
    k = jnp.einsum("bld,dhk->blhk", x, w["wk"])
    v = jnp.einsum("bld,dhk->blhk", x, w["wv"])
    if cfg.qkv_bias:
        q, k, v = q + w["bq"], k + w["bk"], v + w["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, w["q_norm"], kernels=cfg.kernels)
        k = rms_norm(k, w["k_norm"], kernels=cfg.kernels)
    if cfg.use_rope:
        pos = jnp.full((b, 1), index, jnp.int32)
        cos, sin = rotary_embedding(pos, cfg.resolved_head_dim,
                                    cfg.rope_theta)
        q = apply_rotary(q, cos, sin)
        k = apply_rotary(k, cos, sin)

    slot = index % s_max                      # ring slot (SWA caches)
    quantized = "k_scale" in cache
    if quantized:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        ck = jax.lax.dynamic_update_slice(cache["k"], kq, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], vq, (0, slot, 0, 0))
        cks = jax.lax.dynamic_update_slice(cache["k_scale"], ks,
                                           (0, slot, 0))
        cvs = jax.lax.dynamic_update_slice(cache["v_scale"], vs,
                                           (0, slot, 0))
        new_cache = {"k": shard(ck, "batch", "cache_seq", None, None),
                     "v": shard(cv, "batch", "cache_seq", None, None),
                     "k_scale": shard(cks, "batch", "cache_seq", None),
                     "v_scale": shard(cvs, "batch", "cache_seq", None)}
        ck = dequantize_kv(new_cache["k"], new_cache["k_scale"], x.dtype)
        cv = dequantize_kv(new_cache["v"], new_cache["v_scale"], x.dtype)
    else:
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
        ck = shard(ck, "batch", "cache_seq", None, None)
        cv = shard(cv, "batch", "cache_seq", None, None)
        new_cache = {"k": ck, "v": cv}

    # validity of each ring slot for the current query position: a slot
    # s was last written 'age' tokens ago (age = (cur_slot - s) mod S);
    # it holds a real token iff age <= index (cold start: slots "older"
    # than the stream are unwritten -- without this check, empty slots
    # attend as zero-vectors and corrupt the softmax).
    slots = jnp.arange(s_max)
    age = (index % s_max - slots) % s_max
    valid = age <= index
    if cfg.sliding_window is not None:
        valid &= age < cfg.sliding_window
    mask = jnp.broadcast_to(valid[None, :], (1, s_max))
    out = attention(cfg, q, ck, cv, mask=mask)
    # pin the head dim of the tiny (b, 1, hq, hd) activation so the wo
    # projection psums a ~200 KB partial instead of all-gathering the
    # full multi-GB wo weight (measured on mistral decode, §Perf it.11)
    out = shard(out, "batch", None, "heads", None)
    out = jnp.einsum("blhk,hkd->bld", out, w["wo"])
    out = shard(out, "batch", None, None)
    return out, new_cache


# ----------------------------------------------------------------- MLP
def mlp_block(cfg: ModelConfig, x: jax.Array, w: Dict[str, jax.Array]) -> jax.Array:
    if cfg.act == "silu":       # SwiGLU
        gate = jnp.einsum("bld,df->blf", x, w["w_gate"])
        up = jnp.einsum("bld,df->blf", x, w["w_up"])
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    else:                       # GELU (whisper)
        h = jnp.einsum("bld,df->blf", x, w["w_up"]) + w["b_up"]
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    h = shard(h, "batch", None, "model")
    out = jnp.einsum("blf,fd->bld", h, w["w_down"])
    if cfg.act != "silu":
        out = out + w["b_down"]
    return shard(out, "batch", None, None)


# ----------------------------------------------------------------- embeddings
def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    out = jnp.take(table, tokens, axis=0)
    return shard(out, "batch", None, None)


def unembed(x: jax.Array, table: jax.Array,
            vocab_size: Optional[int] = None) -> jax.Array:
    """x (b, l, d) @ table^T (v_padded, d) -> logits (b, l, v_padded).

    Tables are padded so the vocab dim shards (config.padded_vocab);
    padded columns are masked to -1e30 (softmax weight 0, argmax-proof)."""
    logits = jnp.einsum("bld,vd->blv", x, table,
                        preferred_element_type=jnp.float32)
    v_padded = table.shape[0]
    if vocab_size is not None and vocab_size < v_padded:
        col = jnp.arange(v_padded)
        logits = jnp.where(col[None, None, :] < vocab_size, logits,
                           NEG_INF)
    return shard(logits, "batch", None, "model")


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  ignore_id: int = -1) -> jax.Array:
    """Mean token NLL in f32; labels (b, l) with ignore_id masked out."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = logz - gold
    weights = (labels != ignore_id).astype(jnp.float32)
    return jnp.sum(nll * weights) / jnp.maximum(1.0, jnp.sum(weights))
